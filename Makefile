# Developer entry points.  `make tier1` is the CI gate (ROADMAP.md).

PY ?= python

.PHONY: tier1 test-fast bench bench-gemm tune

tier1:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# emits BENCH_GEMM.json (perf trajectory artifact) via benchmarks/common.py
bench-gemm:
	PYTHONPATH=src $(PY) -m benchmarks.run bench_gemm

# warm the on-disk GEMM plan cache for the common shape buckets
tune:
	PYTHONPATH=src $(PY) -c "from repro.gemm import autotune; \
	[autotune(n, n, n) for n in (64, 128, 256)]"
