# Developer entry points.  `make tier1` is the CI gate (ROADMAP.md).

PY ?= python

.PHONY: tier1 test-fast conformance solver-gates sharding-tests \
	chaos-tests bench bench-gemm bench-gemm-mesh bench-smoke \
	bench-accuracy bench-lu tune td-tune ozaki-tune

tier1:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# cross-backend x cross-precision matrix vs the ref oracles (CI job);
# the solver-marked cells are deselected here — among the focused CI
# jobs they run only in solver-gates (tier1 remains the full sweep and
# intentionally covers everything)
conformance:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not solver" \
	tests/test_conformance.py tests/test_accuracy_gate.py

# tiered refinement solver + LAPACK-grade residual gates (CI job): every
# test carrying the `solver` marker — the exact-rational factorization
# gates, the pivot/TRSM property layer, the solver conformance axis
solver-gates:
	PYTHONPATH=src $(PY) -m pytest -x -q -m solver

# every sharding-marked test on a real (forced host-device) 4-device mesh:
# the SUMMA conformance axis runs its 1xN / Nx1 / 2x2 cells instead of
# skipping, plus the 2x2 batched+sharded acceptance subprocess (CI job)
sharding-tests:
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
	PYTHONPATH=src $(PY) -m pytest -x -q -m sharding

# deterministic fault-injection suite (CI's chaos job): every FaultPlan
# injection class — limb flip, NaN/Inf poison, cache corruption, SUMMA
# panel loss, mid-refinement kill, backend failure — must end in a typed
# hazard error or an oracle-conformant recovered result.  Forced host
# devices so the panel-loss cells run on a real 2x2 mesh; writes
# CHAOS_REPORT.json (the hazard-report artifact CI uploads)
chaos-tests:
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
	PYTHONPATH=src $(PY) -m pytest -x -q -m chaos

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# emits BENCH_GEMM.json (perf trajectory artifact) via benchmarks/common.py
bench-gemm:
	PYTHONPATH=src $(PY) -m benchmarks.run bench_gemm

# SUMMA topology sweep (per-mesh GEMM rows in BENCH_GEMM.json); pair with
# forced host devices to fill every topology, as CI's sharding job does
bench-gemm-mesh:
	XLA_FLAGS="$(XLA_FLAGS) --xla_force_host_platform_device_count=4" \
	PYTHONPATH=src $(PY) -m benchmarks.run bench_gemm --mesh 1x1,1x2,2x1,2x2

# every backend x tier at small n, conformance-checked against the ref
# oracle — exits nonzero on a conformance failure (CI's bench-smoke job)
bench-smoke:
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run bench_gemm

# emits BENCH_ACCURACY.json (per-tier observed relative error on the
# exact-rational Hilbert case; the accuracy regression artifact)
bench-accuracy:
	PYTHONPATH=src $(PY) -m benchmarks.run bench_accuracy

# blocked LU + the refinement-ladder sweep; emits BENCH_LU.json (the
# factor-cheap / refine-at-target cost trajectory, uploaded by CI)
bench-lu:
	PYTHONPATH=src $(PY) -m benchmarks.run bench_lu

# warm the on-disk GEMM plan cache for the common shape buckets
tune:
	PYTHONPATH=src $(PY) -c "from repro.gemm import autotune; \
	[autotune(n, n, n) for n in (64, 128, 256)]; \
	[autotune(n, n, n, precision='qd') for n in (64, 128)]"

# warm the td (triple-word) buckets: the systolic tile and the fused
# Ozaki-slice kernel tune independently per limb count (cache schema v4)
td-tune:
	PYTHONPATH=src $(PY) -c "from repro.gemm import autotune; \
	[autotune(n, n, n, precision='td') for n in (64, 128)]; \
	[autotune(n, n, n, backend='ozaki-pallas', precision='td') \
	 for n in (32, 64)]"

# sweep block shapes x n_slices for the fused Ozaki-slice kernel and
# persist the winners (dd tier at common buckets, qd at the small ones)
ozaki-tune:
	PYTHONPATH=src $(PY) -c "from repro.gemm import autotune; \
	[autotune(n, n, n, backend='ozaki-pallas') for n in (32, 64, 128)]; \
	[autotune(n, n, n, backend='ozaki-pallas', precision='qd') \
	 for n in (32, 64)]"
