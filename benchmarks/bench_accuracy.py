"""Paper Eq. 6 / §IV-B1: E_L1 accuracy vs matrix size + the tier gate.

The paper reports E_L1 (mean |difference| vs the reference Rgemm) between
1e-31 and 1e-30 for n < 512, growing to 2e-28 at n = 4096.  We measure the
same metric for dd64 against an exact-direction oracle (ozaki full, which
carries ~2x the bits), plus the f64 'double' control to show the precision
gap the paper's accelerator exists to close.

Also emits ``BENCH_ACCURACY.json``: the per-tier (dd/td/qd) observed
relative error on the exact-rational Hilbert case (core/accuracy.py), per
gated backend, the artifact the accuracy regression gate
(tests/test_accuracy_gate.py) pins and CI uploads.
"""

from __future__ import annotations

import numpy as np

from repro.core import dd, ozaki
from repro.core.accuracy import write_accuracy_json
from repro.core.gemm import matmul
from .common import emit, rand_dd


def run():
    # precision-ladder regression artifact: observed rel. error per tier
    doc = write_accuracy_json("BENCH_ACCURACY.json", n=16)
    for tier, row in doc["tiers"].items():
        emit(f"accuracy_gate/hilbert/{tier}", 0.0,
             f"rel_err={row['rel_err']:.3e};gate={row['gate']:.3e};"
             f"passes={row['passes']}")
    for be, tiers in doc["backends"].items():
        for tier, row in tiers.items():
            emit(f"accuracy_gate/hilbert/{be}/{tier}", 0.0,
                 f"rel_err={row['rel_err']:.3e};gate={row['gate']:.3e};"
                 f"passes={row['passes']}")
    print("# wrote BENCH_ACCURACY.json", flush=True)
    for n in (64, 128, 256):
        a, b = rand_dd((n, n), 11), rand_dd((n, n), 12)
        got = matmul(a, b, backend="ozaki")
        # higher-precision reference: full (untruncated) slice accumulation
        ref = ozaki.ozaki_gemm(a, b, full=True, target_bits=140)
        diff = np.abs(
            (np.asarray(got.hi) - np.asarray(ref.hi))
            + (np.asarray(got.lo) - np.asarray(ref.lo)))
        e_l1 = float(diff.mean())
        # f64 control
        an, bn = np.asarray(dd.to_float(a)), np.asarray(dd.to_float(b))
        e_f64 = float(np.abs(an @ bn - (np.asarray(ref.hi) + np.asarray(ref.lo))).mean())
        emit(f"accuracy_eq6/n={n}", 0.0,
             f"e_l1_dd={e_l1:.2e};e_l1_double={e_f64:.2e};"
             f"paper_band=1e-31..2e-28")
