"""Paper Fig. 2/5: binary128-class GEMM throughput vs matrix size.

CPU-measured GFlops for the three backends (ozaki / xla / pallas-interpret),
plus the f64 'double' control and the TPU-v5e roofline projection for the
Ozaki-on-MXU path (the deployment target; this container has no TPU).

GFlops counts the BINARY128-CLASS operations (2*m*n*k per Eq. 4 of the
paper) — the same accounting the paper uses for its FPGA MACs.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core import dd, ozaki
from repro.core.gemm import matmul
from .common import block, dump_json, emit, rand_dd, time_fn


def projected_tpu_gflops(n: int) -> float:
    """Ozaki-on-MXU effective binary128 GEMM rate on one v5e chip."""
    beta = ozaki.slice_bits(n, jnp.float32, jnp.bfloat16)
    s = ozaki.slice_count(107, beta)
    n_products = s * (s + 1) // 2  # triangular truncation
    return 197e12 / n_products / 1e9


def run():
    for n in (64, 128, 256, 384):
        a, b = rand_dd((n, n), 1), rand_dd((n, n), 2)
        flops = 2.0 * n**3
        for backend in ("ozaki", "xla"):
            t = time_fn(lambda: block(matmul(a, b, backend=backend)))
            emit(f"gemm_fig2/{backend}/n={n}", t * 1e6,
                 f"gflops={flops / t / 1e9:.3f}")
        emit(f"gemm_fig2/tpu_projected/n={n}", 0.0,
             f"gflops={projected_tpu_gflops(n):.1f}")
    # pallas interpret is slow; one size to document correctness-mode cost
    n = 128
    a, b = rand_dd((n, n), 3), rand_dd((n, n), 4)
    t = time_fn(lambda: block(matmul(a, b, backend="pallas", bm=64, bn=64, bk=16)),
                iters=1)
    emit(f"gemm_fig2/pallas_interpret/n={n}", t * 1e6,
         f"gflops={2.0 * n**3 / t / 1e9:.4f}")
    # f64 'double' control (what the paper's CPU baseline does per core)
    import numpy as np

    an, bn = np.asarray(dd.to_float(a)), np.asarray(dd.to_float(b))
    t = time_fn(lambda: an @ bn)
    emit(f"gemm_fig2/f64_numpy/n={n}", t * 1e6,
         f"gflops={2.0 * n**3 / t / 1e9:.1f}")
    # machine-readable perf trajectory artifact (collected by CI)
    dump_json("BENCH_GEMM.json", prefix="gemm_")
