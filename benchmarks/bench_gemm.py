"""Paper Fig. 2/5: binary128-class GEMM throughput vs matrix size.

CPU-measured GFlops for the backends (ozaki / xla / the interpret-mode
Pallas kernels), plus the f64 'double' control and the TPU-v5e roofline
projection for the fused Ozaki-slice kernel (the deployment target; this
container has no TPU).

GFlops counts the BINARY128-CLASS operations (2*m*n*k per Eq. 4 of the
paper) — the same accounting the paper uses for its FPGA MACs.

Smoke mode (``BENCH_SMOKE=1``, CI's bench-smoke job): tiny problems, EVERY
backend x tier cell, and each cell's result is checked against the ref
oracle — a wrong answer fails the benchmark run, so the perf artifact can
never ship numbers from a broken kernel.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core import dd, mp, ozaki
from repro.core.accuracy import max_rel_err
from repro.core.gemm import matmul
from repro.kernels.ref import ddgemm_ref, qdgemm_ref
from .common import (LAST_TIMING, block, dump_json, emit, rand_dd,
                     record_failure, time_fn)

# bf16-sliced conformance floor is coarser than the f64-limb backends'
_SMOKE_TOL = {"dd": 2.0 ** -88, "qd": 2.0 ** -185}


def projected_tpu_gflops(n: int, bk: int = 128) -> float:
    """Fused Ozaki-on-MXU effective binary128 GEMM rate on one v5e chip.

    Models the ozaki-pallas kernel: slices are taken per K-slab (depth
    ``bk``), so the slice count follows the slab fixpoint, not the whole-K
    one — the reason the fused kernel's slice budget stays flat in n.
    """
    beta, s = ozaki.slice_params(min(n, bk), jnp.float32, jnp.bfloat16)
    n_products = s * (s + 1) // 2  # triangular truncation
    return 197e12 / n_products / 1e9


def _rand_tier(precision, shape, seed):
    rng = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(rng.random(shape) - 0.5), precision)


def _smoke():
    """Every backend x tier at small n, conformance-checked vs the oracle."""
    n = 24
    flops = 2.0 * n ** 3
    ref = {"dd": ddgemm_ref, "qd": qdgemm_ref}
    cells = [(be, "dd") for be in ("ozaki", "ozaki-pallas", "xla",
                                   "pallas", "ref")] + \
            [(be, "qd") for be in ("ozaki-pallas", "xla", "pallas", "ref")]
    failures = []
    for backend, precision in cells:
        try:
            a = _rand_tier(precision, (n, n), 1)
            b = _rand_tier(precision, (n, n), 2)
            want = ref[precision](a, b)
            # the conformance call doubles as the timing warmup:
            # interpret-mode cells are slow enough that a third execution
            # per cell matters
            got = block(matmul(a, b, backend=backend))
            err = max_rel_err(got, want)
            ok = err < n * _SMOKE_TOL[precision]
            t = time_fn(lambda: block(matmul(a, b, backend=backend)),
                        warmup=0, iters=1)
            emit(f"gemm_smoke/{backend}/{precision}/n={n}", t * 1e6,
                 f"gflops={flops / t / 1e9:.4f};rel_err={err:.3e};"
                 f"conforms={ok}")
            if not ok:
                failures.append((backend, precision, err))
        except Exception as e:  # noqa: BLE001 — one dead cell must not
            # erase the other cells' rows from the artifact
            record_failure(f"gemm_smoke/{backend}/{precision}/n={n}", e)
            failures.append((backend, precision, f"crashed: {e}"))
    _guard_overhead()
    dump_json("BENCH_GEMM.json", prefix="gemm_")
    if failures:
        raise SystemExit(f"smoke conformance failures: {failures}")


def _guard_overhead():
    """check="finite" cost vs check="none" on the smoke cells.

    Emits the overhead fraction per backend so CI tracks the guarded
    mode's dispatch cost (acceptance: <= 0.15 on these cells; the flags
    ride inside the same jit, so the cost is a few reductions + the
    host-side flag reads).
    """
    n = 24
    a, b = _rand_tier("dd", (n, n), 1), _rand_tier("dd", (n, n), 2)
    for backend in ("ozaki", "xla"):
        try:
            for chk in ("none", "finite"):  # warm both specializations
                block(matmul(a, b, backend=backend, check=chk))
            t0 = time_fn(lambda: block(matmul(a, b, backend=backend,
                                              check="none")),
                         warmup=1, iters=5)
            t1 = time_fn(lambda: block(matmul(a, b, backend=backend,
                                              check="finite")),
                         warmup=1, iters=5)
            emit(f"gemm_guard/{backend}/dd/n={n}", t1 * 1e6,
                 f"overhead={(t1 - t0) / t0:.4f};base_us={t0 * 1e6:.1f}")
        except Exception as e:  # noqa: BLE001
            record_failure(f"gemm_guard/{backend}/dd/n={n}", e)


def _mesh_sweep(mesh_arg: str):
    """SUMMA topology sweep: per-mesh GEMM rates into BENCH_GEMM.json.

    ``mesh_arg``: comma-separated ``RxC`` topologies (``--mesh 1x1,2x2``).
    Each topology times BOTH panel schedules — the ppermute ring (default)
    and the legacy masked-psum broadcast — as separate
    ``gemm_mesh/RxC/{ring,psum}`` rows (median-of-repeats + IQR), with the
    ring row carrying ``speedup_vs_psum`` so the artifact tracks the comm
    rewrite's win per topology.  Topologies needing more devices than the
    process has are reported as skipped rows rather than silently dropped
    (CI's ``sharding`` job forces 4 host devices so the standard sweep
    fills in).  Rates on forced host devices measure the distribution
    overhead, not real multi-chip speedup — the row's value is the
    per-topology *trajectory* across commits.
    """
    import jax
    from jax.sharding import Mesh

    n = 96
    flops = 2.0 * n ** 3
    a, b = rand_dd((n, n), 11), rand_dd((n, n), 12)
    want = ddgemm_ref(a, b)
    for topo in mesh_arg.split(","):
        rows, sep, cols = topo.strip().lower().partition("x")
        if not (sep and rows.isdigit() and cols.isdigit()):
            raise SystemExit(
                f"bad --mesh topology {topo.strip()!r}: want RxC, e.g. "
                f"--mesh=1x2,2x2")
        rows, cols = int(rows), int(cols)
        if jax.device_count() < rows * cols:
            emit(f"gemm_mesh/{rows}x{cols}/n={n}", 0.0,
                 f"skipped=need_{rows * cols}_devices")
            continue
        mesh = Mesh(np.array(jax.devices()[: rows * cols]).reshape(
            rows, cols), ("rows", "cols"))

        def call(comm):
            return block(matmul(a, b, backend="xla", mesh=mesh, comm=comm))

        # warm + conformance-check both schedules before any timing
        errs = {c: max_rel_err(call(c), want) for c in ("psum", "ring")}
        # the two schedules' samples are INTERLEAVED (psum, ring, psum,
        # ring, ...): container CPU throttling drifts over seconds, so
        # timing one schedule's full repeat block after the other's puts
        # the drift entirely into the speedup column — alternating pairs
        # it out of the comparison
        samples = {c: [] for c in errs}
        for _ in range(9):
            for c in errs:
                samples[c].append(time_fn(call, c, warmup=0, iters=1))
        meds = {c: float(np.median(s)) for c, s in samples.items()}
        for comm, t in meds.items():
            q1, q3 = np.percentile(samples[comm], [25.0, 75.0])
            LAST_TIMING.clear()
            LAST_TIMING.update(iters=len(samples[comm]),
                               median_us=t * 1e6,
                               iqr_us=float(q3 - q1) * 1e6)
            derived = (f"gflops={flops / t / 1e9:.4f};"
                       f"rel_err={errs[comm]:.3e};devices={rows * cols}")
            if comm == "ring":
                derived += f";speedup_vs_psum={meds['psum'] / t:.3f}"
            emit(f"gemm_mesh/{rows}x{cols}/{comm}/n={n}", t * 1e6, derived)


def run(mesh: str = ""):
    if mesh:
        _mesh_sweep(mesh)
        dump_json("BENCH_GEMM.json", prefix="gemm_")
        return
    if os.environ.get("BENCH_SMOKE"):
        _smoke()
        return
    for n in (64, 128, 256, 384):
        a, b = rand_dd((n, n), 1), rand_dd((n, n), 2)
        flops = 2.0 * n**3
        for backend in ("ozaki", "xla"):
            # median of 5: containerized CPU throttling swings single
            # wall-clock samples by 2-3x
            t = time_fn(lambda: block(matmul(a, b, backend=backend)),
                        iters=5)
            emit(f"gemm_fig2/{backend}/n={n}", t * 1e6,
                 f"gflops={flops / t / 1e9:.3f}")
        emit(f"gemm_fig2/tpu_projected/n={n}", 0.0,
             f"gflops={projected_tpu_gflops(n):.1f}")
    # pallas interpret is slow; one size each to document correctness-mode
    # cost for the systolic DD kernel and the fused Ozaki-slice kernel
    n = 128
    a, b = rand_dd((n, n), 3), rand_dd((n, n), 4)
    t = time_fn(lambda: block(matmul(a, b, backend="pallas", bm=64, bn=64, bk=16)),
                iters=1)
    emit(f"gemm_fig2/pallas_interpret/n={n}", t * 1e6,
         f"gflops={2.0 * n**3 / t / 1e9:.4f}")
    t = time_fn(lambda: block(matmul(a, b, backend="ozaki-pallas",
                                     bm=64, bn=64, bk=32)), iters=1)
    emit(f"gemm_fig2/ozaki_pallas_interpret/n={n}", t * 1e6,
         f"gflops={2.0 * n**3 / t / 1e9:.4f}")
    # f64 'double' control (what the paper's CPU baseline does per core)
    an, bn = np.asarray(dd.to_float(a)), np.asarray(dd.to_float(b))
    t = time_fn(lambda: an @ bn)
    emit(f"gemm_fig2/f64_numpy/n={n}", t * 1e6,
         f"gflops={2.0 * n**3 / t / 1e9:.1f}")
    # machine-readable perf trajectory artifact (collected by CI)
    dump_json("BENCH_GEMM.json", prefix="gemm_")
