"""Framework-level bench: LM train-step throughput on CPU (reduced configs)

+ the precision-policy cost (the paper's technique inside the LM stack:
lm_head in binary128-class 'dd' mode vs native).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch import steps as S
from repro.launch.train import reduce_cfg
from .common import block, emit, time_fn


def run():
    for arch in ("qwen3-0.6b", "xlstm-350m", "moonshot-v1-16b-a3b"):
        cfg = reduce_cfg(get_config(arch), d_model=128)
        run_cfg = RunConfig(total_steps=10)
        state = S.init_state(cfg, run_cfg, jax.random.PRNGKey(0))
        step = jax.jit(S.build_train_step(cfg, run_cfg))
        rng = np.random.default_rng(0)
        b, s = 4, 128
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        }
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.n_modality_tokens, cfg.d_model), jnp.float32)
        t = time_fn(lambda: block(step(state, batch)[1]["loss"]), warmup=1, iters=2)
        emit(f"lm_train/{arch}", t * 1e6,
             f"tokens_per_s={b * s / t:.0f}")

    # precision-policy: dd lm_head vs native (the paper's engine in the LM)
    cfg = reduce_cfg(get_config("qwen3-0.6b"), d_model=128)
    for mode in ("native", "dd"):
        run_cfg = RunConfig(total_steps=10, policy={"lm_head": mode})
        state = S.init_state(cfg, run_cfg, jax.random.PRNGKey(0))
        step = jax.jit(S.build_train_step(cfg, run_cfg))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        }
        t = time_fn(lambda: block(step(state, batch)[1]["loss"]), warmup=1, iters=2)
        emit(f"lm_policy/lm_head={mode}", t * 1e6, "site=lm_head")
