"""Paper Fig. 8: blocked LU decomposition (Rgetrf) performance.

GFlops = (2/3 n^3) / T  (Eq. 7), block size b swept as in the paper
(their optimum: b=108..144 on Agilex).  Accuracy: max |PA - LU| must sit at
binary128-class levels (paper's E_L1 ~ 1e-31..1e-28).
"""

from __future__ import annotations

import numpy as np

from repro.core import dd
from repro.core.linalg import rgetrf
from .common import emit, rand_dd, time_fn


def run():
    rng = np.random.default_rng(0)
    for n, blocks in ((96, (16, 32)), (192, (16, 32, 64))):
        a = rand_dd((n, n), seed=n)
        for b in blocks:
            t = time_fn(lambda: rgetrf(a, block=b), warmup=1, iters=1)
            lu, piv = rgetrf(a, block=b)
            lu_np = np.asarray(dd.to_float(lu))
            l = np.tril(lu_np, -1) + np.eye(n)
            u = np.triu(lu_np)
            pa = np.asarray(dd.to_float(a)).copy()
            for j, p in enumerate(piv):
                pa[[j, p]] = pa[[p, j]]
            resid = float(np.abs(l @ u - pa).max())
            gflops = (2 / 3) * n**3 / t / 1e9
            emit(f"lu_fig8/n={n}_b={b}", t * 1e6,
                 f"gflops={gflops:.4f};max_resid={resid:.1e}")
