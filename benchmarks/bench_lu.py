"""Paper Fig. 8: blocked LU decomposition (Rgetrf) performance + the
refinement ladder's cost story.

GFlops = (2/3 n^3) / T  (Eq. 7), block size b swept as in the paper
(their optimum: b=108..144 on Agilex).  Accuracy: max |PA - LU| must sit at
binary128-class levels (paper's E_L1 ~ 1e-31..1e-28).

The refinement sweep prices the tiered solver (repro.solve): one
``rgesv`` row per (factor_tier -> target_tier) rung pair — every pair of
the f64 -> dd -> td -> qd ladder, via ``solve.LADDER_CELLS`` — against
the direct solve at the target tier, reporting wall time, refinement
iterations, escalations, and the final backward error.  This is the
paper's application claim in numbers — factoring at a cheap rung and
refining GEMM-rich residuals at the target tier beats paying the
expensive factorization up front.  Emits ``BENCH_LU.json`` (uploaded by
CI's solver-gates job).
"""

from __future__ import annotations

import numpy as np

from repro.core import dd, mp
from repro.core.linalg import lu_solve, rgetrf
from repro.solve import LADDER_CELLS, rgesv
from .common import dump_json, emit, rand_dd, time_fn


def _fig8():
    for n, blocks in ((96, (16, 32)), (192, (16, 32, 64))):
        a = rand_dd((n, n), seed=n)
        for b in blocks:
            t = time_fn(lambda: rgetrf(a, block=b), warmup=1, iters=1)
            lu, piv = rgetrf(a, block=b)
            lu_np = np.asarray(dd.to_float(lu))
            l = np.tril(lu_np, -1) + np.eye(n)
            u = np.triu(lu_np)
            pa = np.asarray(dd.to_float(a)).copy()
            for j, p in enumerate(np.asarray(piv)):
                pa[[j, p]] = pa[[p, j]]
            resid = float(np.abs(l @ u - pa).max())
            gflops = (2 / 3) * n**3 / t / 1e9
            emit(f"lu_fig8/n={n}_b={b}", t * 1e6,
                 f"gflops={gflops:.4f};max_resid={resid:.1e}")


# the sweep: every meaningful rung pair (the solver's own canonical
# table); (tier, tier) rows double as the direct-solve baselines the
# cheap-factor rows are judged against
REFINE_CELLS = LADDER_CELLS


def _refine_sweep(n: int = 48, nrhs: int = 4):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    b = rng.standard_normal((n, nrhs))
    for factor_tier, target_tier in REFINE_CELLS:

        def solve():
            x, info = rgesv(a, b, factor_tier=factor_tier,
                            target_tier=target_tier, backend="xla", block=16)
            mp.limbs(x)[0].block_until_ready()
            return info

        info = solve()  # warmup + report payload
        t = time_fn(lambda: solve(), warmup=0, iters=2)
        emit(f"lu_refine/n={n}_{factor_tier}-to-{target_tier}", t * 1e6,
             f"iters={info.iterations};converged={info.converged};"
             f"escalations={len(info.escalations)};"
             f"berr={info.final_backward_error:.1e}")

    # qd-direct full solve (factor + substitutions, no refinement): the
    # ceiling the dd->qd row undercuts
    a_qd = mp.from_float(np.asarray(a, np.float64), "qd")
    b_qd = mp.from_float(np.asarray(b, np.float64), "qd")

    def direct():
        lu, piv = rgetrf(a_qd, block=16)
        x = lu_solve(lu, piv, b_qd)
        mp.limbs(x)[0].block_until_ready()

    direct()
    t = time_fn(direct, warmup=0, iters=2)
    emit(f"lu_refine/n={n}_qd-direct", t * 1e6, "iters=0;converged=True")


def run():
    _fig8()
    _refine_sweep()
    dump_json("BENCH_LU.json", prefix="lu_")
