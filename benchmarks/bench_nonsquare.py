"""Paper Fig. 4/6: non-square GEMM — m=k fixed, n swept (and k swept).

The paper's systolic array collapses on tall-skinny shapes (PE starvation).
The TPU port's failure mode differs: throughput follows the arithmetic
intensity of the shape, so efficiency falls once n (or k) is too small to
amortize operand traffic — same qualitative cliff, different mechanism
(documented in EXPERIMENTS.md).
"""

from __future__ import annotations

from repro.core.gemm import matmul
from .common import block, emit, rand_dd, time_fn


def run():
    mk = 256
    for n in (16, 32, 64, 128, 256):
        a, b = rand_dd((mk, mk), 7), rand_dd((mk, n), 8)
        flops = 2.0 * mk * mk * n
        t = time_fn(lambda: block(matmul(a, b, backend="ozaki")))
        emit(f"nonsquare_fig4/n={n}", t * 1e6,
             f"gflops={flops / t / 1e9:.3f}")
    for k in (16, 32, 64, 128, 256):
        a, b = rand_dd((mk, k), 9), rand_dd((k, mk), 10)
        flops = 2.0 * mk * mk * k
        t = time_fn(lambda: block(matmul(a, b, backend="ozaki")))
        emit(f"nonsquare_fig6/k={k}", t * 1e6,
             f"gflops={flops / t / 1e9:.3f}")
