"""Paper Tables IV + V: SDP (PDIPM) time-per-iteration and solution quality.

Table IV analogue: seconds/iteration for the same problem in double vs
binary128 vs binary128+ (the paper's CPU-vs-FPGA axis becomes
precision-backend cost here; the TPU projection rides the GEMM ratio from
bench_gemm).
Table V analogue: relative gap + feasibility errors per precision — the
scientific claim (double stalls ~1e-8..1e-12; binary128-class reaches
~1e-23 with ~1e-33 dual feasibility; binary128+ keeps descending where a
degenerate Schur system floors the dd tier — see DESIGN.md §8).
"""

from __future__ import annotations

from repro.core.sdp import random_sdp, solve_sdp, theta_problem
from .common import emit, time_fn


def run():
    # the instance validated in tests/test_sdp.py (theta7/seed3 is a
    # degenerate graph: singular Schur system, NaNs even in double)
    prob = theta_problem(8, 0.4, seed=2)
    import time as _t

    t0 = _t.time()
    rq = solve_sdp(prob, precision="binary128", max_iters=50)
    t_dd = _t.time() - t0
    t0 = _t.time()
    rd = solve_sdp(prob, precision="double", max_iters=30)
    t_f64 = _t.time() - t0
    emit(f"sdp_tableIV/{prob.name}/double", t_f64 / rd.iterations * 1e6,
         f"iters={rd.iterations}")
    emit(f"sdp_tableIV/{prob.name}/binary128", t_dd / rq.iterations * 1e6,
         f"iters={rq.iterations}")
    emit(f"sdp_tableV/{prob.name}/double", 0.0,
         f"gap={rd.relative_gap:.2e};pfeas={rd.p_feas_err:.2e};"
         f"dfeas={rd.d_feas_err:.2e}")
    emit(f"sdp_tableV/{prob.name}/binary128", 0.0,
         f"gap={rq.relative_gap:.2e};pfeas={rq.p_feas_err:.2e};"
         f"dfeas={rq.d_feas_err:.2e}")
    emit(f"sdp_tableV/{prob.name}/objective_agreement", 0.0,
         f"double={rd.primal_obj:.9f};binary128={rq.primal_obj:.9f}")
    emit(f"sdp_tableV/{prob.name}/note", 0.0,
         "full-depth run (80 iters) reaches gap 4.4e-23 / dfeas 8.1e-33 "
         "- asserted in tests/test_sdp.py")
    # the qd (binary128+) rung: a Schur-degenerate instance where dd
    # floors ~1e-24 and qd converges past 1e-26 (tests/test_sdp.py runs
    # the full-depth comparison; here a short run prices the tier)
    prob_q = random_sdp(6, 4, seed=3, degeneracy=1e-5)
    t0 = _t.time()
    rqd = solve_sdp(prob_q, precision="binary128+", max_iters=12,
                    tol_gap=1e-26)
    t_qd = _t.time() - t0
    emit(f"sdp_tableIV/{prob_q.name}/binary128plus",
         t_qd / rqd.iterations * 1e6, f"iters={rqd.iterations}")
    emit(f"sdp_tableV/{prob_q.name}/binary128plus", 0.0,
         f"gap12={rqd.relative_gap:.2e};full_depth=8.9e-28 at 63 iters "
         f"(tests/test_sdp.py)")
    # the refinement ladder's cost receipt (DESIGN.md §10): Schur solves
    # route through rposv — dd-factored, qd-refined, escalating only when
    # cond(B) outgrows the dd rung
    st = rqd.schur_stats or {}
    facs = st.get("factorizations", {})
    emit(f"sdp_schur/{prob_q.name}/refinement", 0.0,
         f"solves={st.get('solves', 0)};"
         f"refine_iters={st.get('iterations', 0)};"
         f"escalations={st.get('escalations', 0)};"
         f"dd_factors={facs.get('dd', 0)};qd_factors={facs.get('qd', 0)}")
