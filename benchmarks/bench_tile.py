"""Paper Fig. 3 + Tables II/III: the M_Tile analogue — BlockSpec sweep.

The paper sweeps the per-PE memory tile (M_Tile) and reports synthesis
results per PE-array size.  The TPU analogue: sweep the Pallas kernel's
(bm, bn, bk) block shapes, report the VMEM working set each claims (the
"synthesis" constraint: must fit ~16 MB v5e VMEM), the F_peak model, and
the bandwidth requirement B_req — Eqs. (3) and (5) re-derived for the port.

The resource models and the sweep itself now live in the engine's autotuner
(``repro.gemm.autotune``); this benchmark drives them to produce the
figure *and* leaves the winner in the on-disk plan cache, so a benchmark
run doubles as a tuning run for subsequent workloads in the same buckets.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.gemm import make_plan
from repro.gemm.autotune import (FLOPS_PER_DD_FMA, HBM_GBPS, VMEM_BYTES,
                                 autotune, bandwidth_req_gbps,
                                 f_peak_gflops, vmem_bytes)
from repro.kernels.ops import ddgemm
from .common import block, emit, rand_dd, time_fn


def run():
    n = 128
    a, b = rand_dd((n, n), 5), rand_dd((n, n), 6)
    f_peak = f_peak_gflops()
    emit("tile_tableII/f_peak_model", 0.0,
         f"gflops={f_peak:.1f};flops_per_fma={FLOPS_PER_DD_FMA}")
    for bm, bn, bk in [(32, 32, 8), (64, 64, 8), (64, 64, 32),
                       (128, 128, 16), (128, 128, 64)]:
        vm = vmem_bytes(bm, bn, bk)
        breq = bandwidth_req_gbps(bm, bn, f_peak * 1e9)
        t = time_fn(
            lambda: block(ddgemm(a, b, bm=bm, bn=bn, bk=bk)), iters=1)
        emit(f"tile_fig3/bm{bm}_bn{bn}_bk{bk}", t * 1e6,
             f"vmem_kb={vm / 1024:.0f};fits_vmem={vm < VMEM_BYTES};"
             f"b_req_gbps={breq:.1f};b_req_ok={breq < HBM_GBPS}")
    # autotune a smaller bucket (interpret-mode timing keeps this cheap):
    # persists the winner so plan() reuses it across later calls/processes
    nt = 64
    cands = [{"bm": 32, "bn": 32, "bk": 8}, {"bm": 32, "bn": 32, "bk": 32},
             {"bm": 64, "bn": 64, "bk": 16}]
    plan = autotune(nt, nt, nt, dtype=jnp.float64, candidates=cands,
                    iters=1)
    emit(f"tile_autotune/n={nt}", 0.0,
         f"bm={plan.bm};bn={plan.bn};bk={plan.bk}")
    replanned = make_plan(nt, nt, nt, backend="pallas")
    emit("tile_autotune/replanned_source", 0.0,
         f"source={replanned.source};bm={replanned.bm}")
