"""Paper Fig. 3 + Tables II/III: the M_Tile analogue — BlockSpec sweep.

The paper sweeps the per-PE memory tile (M_Tile) and reports synthesis
results per PE-array size.  The TPU analogue: sweep the Pallas kernel's
(bm, bn, bk) block shapes, report the VMEM working set each claims (the
"synthesis" constraint: must fit ~16 MB v5e VMEM), the F_peak model, and
the bandwidth requirement B_req — Eqs. (3) and (5) re-derived for the port:

  F_peak = peak_f32_flops / flops_per_dd_fma      (VPU path)
  B_req  = (bm + bn) / (bm * bn) * F_peak/2 * 32B  (bytes/s to stream A,B)

plus measured interpret-mode wall time per block shape (relative ordering).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ops import ddgemm
from .common import block, emit, rand_dd, time_fn

# measured static op count of one DD multiply-add (two_prod + dd add chain)
FLOPS_PER_DD_FMA = 86
V5E_F32_FLOPS = 197e12 / 2  # VPU f32 is ~half the bf16 MXU rate
VMEM_BYTES = 16 * 2**20


def vmem_bytes(bm, bn, bk, limb_bytes=4):
    # a-tile + b-tile + 2 accumulators, 2 limbs each
    return 2 * limb_bytes * (bm * bk + bk * bn + 2 * bm * bn)


def run():
    n = 128
    a, b = rand_dd((n, n), 5), rand_dd((n, n), 6)
    f_peak = V5E_F32_FLOPS / FLOPS_PER_DD_FMA / 1e9  # binary128-class GFlops
    emit("tile_tableII/f_peak_model", 0.0,
         f"gflops={f_peak:.1f};flops_per_fma={FLOPS_PER_DD_FMA}")
    for bm, bn, bk in [(32, 32, 8), (64, 64, 8), (64, 64, 32),
                       (128, 128, 16), (128, 128, 64)]:
        vm = vmem_bytes(bm, bn, bk)
        breq = (bm + bn) / (bm * bn) * (f_peak * 1e9 / 2) * 32 / 1e9
        t = time_fn(
            lambda: block(ddgemm(a, b, bm=bm, bn=bn, bk=bk)), iters=1)
        emit(f"tile_fig3/bm{bm}_bn{bn}_bk{bk}", t * 1e6,
             f"vmem_kb={vm / 1024:.0f};fits_vmem={vm < VMEM_BYTES};"
             f"b_req_gbps={breq:.1f};b_req_ok={breq < 819}")
