"""Shared benchmark helpers: timing + CSV emission."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (fn must block, e.g. via block_until_ready)."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x)


def rand_dd(shape, seed=0, dtype=jnp.float64):
    from repro.core import dd

    rng = np.random.default_rng(seed)
    return dd.from_float(jnp.asarray(rng.random(shape) - 0.5, dtype))
