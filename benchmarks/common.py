"""Shared benchmark helpers: timing + CSV emission + JSON artifacts."""

from __future__ import annotations

import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []
RESULTS = []  # structured mirror of ROWS for JSON artifacts

# repeat stats of the most recent time_fn call; emit() merges them into
# its row (and clears them, so rows that were never timed — projections,
# skip markers — cannot inherit a stale spread)
LAST_TIMING: dict = {}


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = {"True": True, "False": False}.get(v, v)
    return out


def emit(name: str, us_per_call: float, derived: str):
    stats = dict(LAST_TIMING)
    LAST_TIMING.clear()
    if stats:
        derived = derived + ";" + ";".join(
            f"{k}={v:.1f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in stats.items())
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RESULTS.append({"name": name, "us_per_call": us_per_call,
                    **_parse_derived(derived)})
    print(row, flush=True)


def record_failure(name: str, error: BaseException) -> None:
    """Record a crashed bench cell as a structured row and keep sweeping.

    The row lands in the same JSON artifact as timings —
    ``{"name": ..., "error": "ExcType: msg"}`` — so a perf trajectory
    survives one bad cell (the cells after it still run and upload) and
    the regression tooling sees WHICH cell died instead of an empty
    artifact.
    """
    msg = f"{type(error).__name__}: {error}"
    RESULTS.append({"name": name, "error": msg[:500]})
    print(f"# FAILED {name}: {msg}", flush=True)


def dump_json(path: str, prefix: str | None = None) -> str:
    """Write the rows emitted so far (optionally name-filtered) as JSON.

    A perf artifact, so the repo's throughput trajectory is machine-readable
    across commits (CI uploads it per run)."""
    rows = [r for r in RESULTS
            if prefix is None or r["name"].startswith(prefix)]
    doc = {
        "schema": "repro-bench/v1",
        "unix_time": time.time(),
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {path} ({len(rows)} rows)", flush=True)
    return path


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (fn must block, e.g. block_until_ready).

    Defaults to 5 repeats — containerized CPU throttling swings single
    samples by 2-3x, so every standing-sweep row is a median-of-repeats
    (cells that are minutes-per-call, e.g. interpret-mode Pallas, may
    pass a smaller ``iters`` explicitly).  The repeat spread lands in
    ``LAST_TIMING`` as ``{iters, median_us, iqr_us}``; the next ``emit``
    call merges it into its row, so the artifact records both the center
    and the noise of every timing.
    """
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    if len(times) > 1:
        q1, q3 = np.percentile(times, [25.0, 75.0])
        iqr = float(q3 - q1)
    else:
        iqr = 0.0
    LAST_TIMING.clear()
    LAST_TIMING.update(iters=len(times), median_us=med * 1e6,
                       iqr_us=iqr * 1e6)
    return med


def block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x)


def rand_dd(shape, seed=0, dtype=jnp.float64):
    from repro.core import dd

    rng = np.random.default_rng(seed)
    return dd.from_float(jnp.asarray(rng.random(shape) - 0.5, dtype))
