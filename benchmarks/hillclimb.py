"""Perf hillclimb driver (§Perf methodology): re-lower a cell with a named
change and print before/after roofline terms.

Cells (chosen per the task spec):
  A: qwen3-4b x train_4k        — paper-representative dense-GEMM training
  B: llama3-405b x train_4k     — worst roofline fraction at baseline
  C: qwen3-moe-235b x train_4k  — most collective-bound large cell

Changes are expressed as (run_overrides, rules_overrides) pairs so each
experiment is one CLI invocation:

  PYTHONPATH=src python -m benchmarks.hillclimb A dp_only
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402
import sys  # noqa: E402

CELLS = {
    "A": ("qwen3-4b", "train_4k"),
    "B": ("llama3-405b", "train_4k"),
    "C": ("qwen3-moe-235b-a22b", "train_4k"),
    "A32": ("qwen3-4b", "prefill_32k"),
}


def dp_only_rules(rules):
    """Disable tensor parallelism: pure DP+ZeRO over all 256/512 chips.

    Small/medium models pay more for TP activation all-reduces than the
    matmul sharding saves; batch and parameters shard over the WHOLE mesh.
    """
    import dataclasses

    pr = dict(rules.param_rules)
    ar = dict(rules.act_rules)
    every = ("pod", "data", "model")
    for k in ("heads", "kv_heads", "ffn", "vocab", "experts"):
        pr[k] = None
    pr["embed"] = every
    ar["batch"] = every
    for k in ("heads", "kv_heads", "ffn", "vocab", "seq_res", "experts"):
        ar[k] = None
    return dataclasses.replace(rules, param_rules=pr, act_rules=ar)


def ep_only_rules(rules):
    """MoE: keep EP (experts on model axis) but drop attention/vocab TP."""
    import dataclasses

    pr = dict(rules.param_rules)
    ar = dict(rules.act_rules)
    for k in ("heads", "kv_heads", "vocab"):
        pr[k] = None
        ar[k] = None
    pr["ffn"] = None
    ar["ffn"] = None
    ar["seq_res"] = None
    return dataclasses.replace(rules, param_rules=pr, act_rules=ar)


CHANGES = {
    "baseline": ({}, None),
    "dp_only": ({}, dp_only_rules),
    "dp_only_mb1": ({"microbatches": 1}, dp_only_rules),
    "dp_only_bf16": ({"microbatches": 1, "param_dtype": "bfloat16",
                      "optimizer": "adamw_int8"}, dp_only_rules),
    "mb4": ({"microbatches": 4}, None),
    "mb2": ({"microbatches": 2}, None),
    "mb4_bf16": ({"microbatches": 4, "param_dtype": "bfloat16",
                  "optimizer": "adamw_int8"}, None),
    "dp_only_mb4": ({"microbatches": 4, "param_dtype": "bfloat16",
                     "optimizer": "adamw_int8"}, dp_only_rules),
    "ep_only": ({}, ep_only_rules),
    "ep_only_mb4": ({"microbatches": 4}, ep_only_rules),
    "remat_dots": ({"remat": "dots"}, None),
}


def main():
    from repro.launch.dryrun import lower_cell

    cell = CELLS[sys.argv[1]]
    change = sys.argv[2] if len(sys.argv) > 2 else "baseline"
    run_overrides, rules_fn = CHANGES[change]
    rec = lower_cell(cell[0], cell[1], run_overrides=run_overrides,
                     rules_overrides=rules_fn)
    if "error" in rec:
        print(f"FAIL {cell} {change}: {rec['error']}")
        print(rec.get("traceback", "")[-1500:])
        return 1
    r = rec["roofline"]
    print(json.dumps({
        "cell": f"{cell[0]} x {cell[1]}", "change": change,
        "mem_gb": round(rec["memory_per_device"]["peak_estimate"] / 1e9, 2),
        "compute_ms": round(r["compute_s"] * 1e3, 1),
        "memory_ms": round(r["memory_s"] * 1e3, 1),
        "collective_ms": round(r["collective_s"] * 1e3, 1),
        "bottleneck": r["bottleneck"],
        "useful_ratio": round(r["useful_ratio"], 3),
        "roofline_fraction": round(r["roofline_fraction"], 4),
        "collectives": {k: round(v / 1e9, 1) for k, v in
                        rec["collective_bytes"].items() if k != "total"},
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
