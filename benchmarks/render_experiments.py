"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun_results.json."""

import json
import sys


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def render(path="benchmarks/dryrun_results.json", mesh="16x16"):
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for r in recs:
        if r.get("mesh") != mesh and not r.get("skipped"):
            continue
        if r.get("skipped"):
            if mesh == "16x16":
                rows.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                    f"SKIP: {r['skipped']} |")
            continue
        roof = r["roofline"]
        rows.append(
            "| {arch} | {shape} | {mem} | {c:.1f} | {m:.1f} | {k:.1f} | "
            "{bot} | {ur:.2f} | {frac:.3f} |  |".format(
                arch=r["arch"], shape=r["shape"],
                mem=fmt_bytes(r["memory_per_device"]["peak_estimate"]),
                c=roof["compute_s"] * 1e3, m=roof["memory_s"] * 1e3,
                k=roof["collective_s"] * 1e3, bot=roof["bottleneck"],
                ur=roof["useful_ratio"], frac=roof["roofline_fraction"]))
    seen = set()
    uniq = []
    for row in rows:
        key = row.split("|")[1:3]
        k = tuple(key)
        if k in seen:
            continue
        seen.add(k)
        uniq.append(row)
    hdr = ("| arch | shape | mem/dev GB | compute ms | memory ms | "
           "collective ms | bottleneck | useful ratio | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    print(hdr)
    print("\n".join(uniq))


if __name__ == "__main__":
    render(mesh=sys.argv[1] if len(sys.argv) > 1 else "16x16")
