"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Mapping to the paper (also in DESIGN.md §7):

  bench_gemm       Fig. 2/5   GEMM GFlops vs n, per backend + TPU projection
  bench_tile       Fig. 3 + Tables II/III   BlockSpec (M_Tile) sweep
  bench_nonsquare  Fig. 4/6   tall-skinny shapes
  bench_accuracy   Eq. 6      E_L1 accuracy bands
  bench_lu         Fig. 8     blocked LU (Rgetrf) + block-size sweep
  bench_sdp        Tables IV/V   PDIPM time/iter + solution quality
  bench_lm         framework: LM train-step throughput + precision policy
"""

import inspect
import sys
import time


def _parse_argv(argv):
    """Split ``[module-filter] [--flag value | --flag=value ...]``.

    Flags become keyword options handed to any benchmark whose ``run()``
    accepts them (e.g. ``bench_gemm --mesh 2x2,1x4`` drives the SUMMA
    topology sweep); positional args filter which modules run.
    """
    only, opts = None, {}
    it = iter(argv)
    for arg in it:
        if arg.startswith("--"):
            key, eq, val = arg[2:].partition("=")
            if not eq:
                val = next(it, None)
                if val is None or val.startswith("--"):
                    # a valueless flag must fail here, not silently bind ""
                    # and run the (possibly minutes-long) default suite
                    raise SystemExit(
                        f"--{key} requires a value (use --{key}=VALUE)")
            opts[key.replace("-", "_")] = val
        elif only is None:
            only = arg
    return only, opts


# artifact each module contributes to: (path, row-name prefix).  On a
# module crash the sweep re-dumps this artifact so the rows (including the
# structured failure row) survive the crash and still upload from CI.
_ARTIFACTS = {
    "bench_gemm": ("BENCH_GEMM.json", "gemm_"),
    "bench_tile": ("BENCH_GEMM.json", "gemm_"),
    "bench_nonsquare": ("BENCH_GEMM.json", "gemm_"),
    "bench_lu": ("BENCH_LU.json", "lu_"),
}


def main() -> None:
    t0 = time.time()
    from . import (bench_accuracy, bench_gemm, bench_lm, bench_lu,
                   bench_nonsquare, bench_sdp, bench_tile)
    from . import common

    print("name,us_per_call,derived")
    only, opts = _parse_argv(sys.argv[1:])
    selected = [mod for mod in (bench_gemm, bench_tile, bench_nonsquare,
                                bench_accuracy, bench_lu, bench_sdp,
                                bench_lm)
                if not only or only in mod.__name__]
    accepted = {mod: {k for k in opts
                      if k in inspect.signature(mod.run).parameters}
                for mod in selected}
    unknown = opts.keys() - set().union(*accepted.values(), set())
    if unknown:
        # a misspelled flag must fail up front, not silently run the
        # (possibly minutes-long) default suite first
        raise SystemExit(
            f"unknown option(s) {sorted(unknown)}: no selected "
            f"benchmark's run() accepts them")
    failed = []
    for mod in selected:
        print(f"# {mod.__name__} — {mod.__doc__.strip().splitlines()[0]}",
              flush=True)
        short = mod.__name__.rsplit(".", 1)[-1]
        try:
            mod.run(**{k: opts[k] for k in accepted[mod]})
        # SystemExit passes through untouched: it is a *verdict* (the
        # bench-smoke conformance gate failing), not a crashed cell
        except Exception as e:  # noqa: BLE001 — sweep survival is the point
            failed.append(short)
            art = _ARTIFACTS.get(short)
            common.record_failure(
                ((art[1] if art else "") + f"error/{short}"), e)
            if art is not None:
                # re-dump so the rows emitted before the crash (plus the
                # failure row) reach the artifact the crash preempted
                common.dump_json(art[0], prefix=art[1])
    print(f"# total {time.time() - t0:.0f}s")
    if failed:
        # exit 0 on purpose: the artifact row + "# FAILED" comments carry
        # the failure; a nonzero exit would skip CI's artifact upload and
        # destroy the very perf trajectory this path preserves
        print(f"# FAILED: {', '.join(failed)} (see error rows)",
              flush=True)


if __name__ == '__main__':
    main()
