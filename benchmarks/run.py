"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Mapping to the paper (also in DESIGN.md §7):

  bench_gemm       Fig. 2/5   GEMM GFlops vs n, per backend + TPU projection
  bench_tile       Fig. 3 + Tables II/III   BlockSpec (M_Tile) sweep
  bench_nonsquare  Fig. 4/6   tall-skinny shapes
  bench_accuracy   Eq. 6      E_L1 accuracy bands
  bench_lu         Fig. 8     blocked LU (Rgetrf) + block-size sweep
  bench_sdp        Tables IV/V   PDIPM time/iter + solution quality
  bench_lm         framework: LM train-step throughput + precision policy
"""

import inspect
import sys
import time


def _parse_argv(argv):
    """Split ``[module-filter] [--flag value | --flag=value ...]``.

    Flags become keyword options handed to any benchmark whose ``run()``
    accepts them (e.g. ``bench_gemm --mesh 2x2,1x4`` drives the SUMMA
    topology sweep); positional args filter which modules run.
    """
    only, opts = None, {}
    it = iter(argv)
    for arg in it:
        if arg.startswith("--"):
            key, eq, val = arg[2:].partition("=")
            if not eq:
                val = next(it, None)
                if val is None or val.startswith("--"):
                    # a valueless flag must fail here, not silently bind ""
                    # and run the (possibly minutes-long) default suite
                    raise SystemExit(
                        f"--{key} requires a value (use --{key}=VALUE)")
            opts[key.replace("-", "_")] = val
        elif only is None:
            only = arg
    return only, opts


def main() -> None:
    t0 = time.time()
    from . import (bench_accuracy, bench_gemm, bench_lm, bench_lu,
                   bench_nonsquare, bench_sdp, bench_tile)

    print("name,us_per_call,derived")
    only, opts = _parse_argv(sys.argv[1:])
    selected = [mod for mod in (bench_gemm, bench_tile, bench_nonsquare,
                                bench_accuracy, bench_lu, bench_sdp,
                                bench_lm)
                if not only or only in mod.__name__]
    accepted = {mod: {k for k in opts
                      if k in inspect.signature(mod.run).parameters}
                for mod in selected}
    unknown = opts.keys() - set().union(*accepted.values(), set())
    if unknown:
        # a misspelled flag must fail up front, not silently run the
        # (possibly minutes-long) default suite first
        raise SystemExit(
            f"unknown option(s) {sorted(unknown)}: no selected "
            f"benchmark's run() accepts them")
    for mod in selected:
        print(f"# {mod.__name__} — {mod.__doc__.strip().splitlines()[0]}",
              flush=True)
        mod.run(**{k: opts[k] for k in accepted[mod]})
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == '__main__':
    main()
