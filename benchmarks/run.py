"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
Mapping to the paper (also in DESIGN.md §7):

  bench_gemm       Fig. 2/5   GEMM GFlops vs n, per backend + TPU projection
  bench_tile       Fig. 3 + Tables II/III   BlockSpec (M_Tile) sweep
  bench_nonsquare  Fig. 4/6   tall-skinny shapes
  bench_accuracy   Eq. 6      E_L1 accuracy bands
  bench_lu         Fig. 8     blocked LU (Rgetrf) + block-size sweep
  bench_sdp        Tables IV/V   PDIPM time/iter + solution quality
  bench_lm         framework: LM train-step throughput + precision policy
"""

import sys
import time


def main() -> None:
    t0 = time.time()
    from . import (bench_accuracy, bench_gemm, bench_lm, bench_lu,
                   bench_nonsquare, bench_sdp, bench_tile)

    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for mod in (bench_gemm, bench_tile, bench_nonsquare, bench_accuracy,
                bench_lu, bench_sdp, bench_lm):
        if only and only not in mod.__name__:
            continue
        print(f"# {mod.__name__} — {mod.__doc__.strip().splitlines()[0]}",
              flush=True)
        mod.run()
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == '__main__':
    main()
