"""Blocked LU decomposition in binary128-class arithmetic (paper §V-A).

Factorizes a random [0,1) matrix (the paper's test), solves a linear
system, and shows the residual gap vs double precision.

    PYTHONPATH=src python examples/lu_decomposition.py [n]
"""

import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.core import dd
from repro.core.linalg import lu_solve, rgetrf


def main(n: int = 128):
    rng = np.random.default_rng(1)
    a_np = rng.random((n, n))
    a = dd.from_float(jnp.asarray(a_np))

    t0 = time.time()
    lu, piv = rgetrf(a, block=32)
    t = time.time() - t0
    gflops = (2 / 3) * n**3 / t / 1e9
    print(f"rgetrf(n={n}, b=32): {t:.2f}s  ({gflops:.4f} binary128-GFlops; "
          f"paper Agilex: 2.5 GFlops at n=20000)")

    lu_np = np.asarray(dd.to_float(lu))
    l = np.tril(lu_np, -1) + np.eye(n)
    u = np.triu(lu_np)
    pa = a_np.copy()
    for j, p in enumerate(piv):
        pa[[j, p]] = pa[[p, j]]
    print(f"max |PA - LU| (f64 view)   = {np.abs(l @ u - pa).max():.3e}")

    # solve A x = b and compare residual against plain f64 LU
    x_true = rng.standard_normal((n, 1))
    b = a_np @ x_true
    x = lu_solve(lu, piv, dd.from_float(jnp.asarray(b)))
    r_dd = np.abs(a_np @ np.asarray(dd.to_float(x)) - b).max()
    x64 = np.linalg.solve(a_np, b)
    r_64 = np.abs(a_np @ x64 - b).max()
    print(f"residual |Ax-b|: binary128-class {r_dd:.3e}  vs double {r_64:.3e}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 128)
