"""Quickstart: binary128-class GEMM in three backends + the accuracy story.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import dd
from repro.core.blas import rgemm
from repro.core.gemm import matmul
from repro.solve import rgesv


def main():
    rng = np.random.default_rng(0)
    n = 96
    a = dd.from_float(jnp.asarray(rng.random((n, n))))
    b = dd.from_float(jnp.asarray(rng.random((n, n))))

    print("== C = A @ B in binary128-class (double-word) arithmetic ==")
    c_ozaki = matmul(a, b, backend="ozaki")    # error-free slices on native GEMM
    c_pallas = matmul(a, b, backend="pallas")  # the paper's systolic design
    c_xla = matmul(a, b, backend="xla")        # per-element DD fallback

    for name, c in (("ozaki", c_ozaki), ("pallas", c_pallas), ("xla", c_xla)):
        d = np.abs((np.asarray(c.hi) - np.asarray(c_ozaki.hi))
                   + (np.asarray(c.lo) - np.asarray(c_ozaki.lo))).max()
        print(f"  {name:7s} max |diff vs ozaki| = {d:.3e}")

    print("\n== the precision gap the paper closes ==")
    an, bn = np.asarray(dd.to_float(a)), np.asarray(dd.to_float(b))
    e_f64 = np.abs(an @ bn - (np.asarray(c_ozaki.hi) + np.asarray(c_ozaki.lo))).mean()
    print(f"  E_L1(double vs binary128-class) = {e_f64:.3e}  "
          "(paper: double is 100-1000x slower to fix on CPU)")

    print("\n== Rgemm API (paper Listing 1): C = alpha*op(A)@op(B) + beta*C ==")
    c0 = dd.from_float(jnp.asarray(rng.random((n, n))))
    out = rgemm("n", "t", 2.0, a, b, -1.0, c0)
    ref = 2.0 * (an @ bn.T) - np.asarray(dd.to_float(c0))
    print(f"  max |rgemm - numpy f64 ref| = "
          f"{np.abs(np.asarray(dd.to_float(out)) - ref).max():.3e} "
          "(f64-level agreement; dd carries ~1e-32 internally)")

    print("\n== tiered refinement solve (repro.solve, DESIGN.md §10) ==")
    a_np = np.asarray(rng.random((n, n))) + n * np.eye(n)
    b_np = a_np @ rng.standard_normal((n, 2))
    # factor once at plain f64, refine residuals at the dd tier through
    # the engine (r = b - A x is ONE fused-epilogue GEMM per iteration)
    x, info = rgesv(a_np, b_np, factor_tier="f64", target_tier="dd")
    print(f"  rgesv f64-factor -> dd-refine: converged={info.converged} "
          f"in {info.iterations} iterations")
    print("  backward errors per iteration:",
          " ".join(f"{e:.1e}" for e in info.backward_errors))


if __name__ == "__main__":
    main()
