"""SDP via PDIPM in double vs binary128-class precision (paper §V-B).

Solves a Lovasz-theta problem (the paper's SDPLIB 'theta*' family) both
ways and prints the Table-V-style comparison: double stalls near 1e-8..
1e-12 relative gap, binary128-class pushes to ~1e-23.

    PYTHONPATH=src python examples/sdp_solver.py
"""

import time

from repro.core.sdp import solve_sdp, theta_problem


def main():
    prob = theta_problem(8, 0.4, seed=2)
    print(f"Lovasz theta SDP: n={prob.n}, m={prob.m} constraints\n")

    rows = []
    for precision, iters in (("double", 40), ("binary128", 80)):
        t0 = time.time()
        res = solve_sdp(prob, precision=precision, max_iters=iters)
        rows.append((precision, res, time.time() - t0))

    print(f"{'':16s}{'double':>14s}{'binary128':>14s}   (paper Table V)")
    labels = [
        ("relative gap", lambda r: f"{r.relative_gap:.2e}", "1e-24 vs 1e-08"),
        ("p.feas.error", lambda r: f"{r.p_feas_err:.2e}", "1e-32 vs 1e-15"),
        ("d.feas.error", lambda r: f"{r.d_feas_err:.2e}", "1e-25 vs 1e-14"),
        ("# iterations", lambda r: str(r.iterations), "45-94 vs 17-47"),
        ("theta number", lambda r: f"{-r.primal_obj:.6f}", ""),
    ]
    for name, fn, paper in labels:
        print(f"{name:16s}{fn(rows[0][1]):>14s}{fn(rows[1][1]):>14s}   {paper}")
    print(f"{'seconds/iter':16s}"
          f"{rows[0][2] / rows[0][1].iterations:>14.2f}"
          f"{rows[1][2] / rows[1][1].iterations:>14.2f}")


if __name__ == "__main__":
    main()
