"""Batched serving demo: continuous-batching greedy decode on CPU.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
