"""End-to-end LM training on CPU: ~100M-class reduced qwen3 config, a few

hundred steps on the deterministic synthetic pipeline, with checkpointing,
a mid-run SIMULATED FAILURE (restored automatically), and the paper's
technique enabled at the lm_head (precision policy).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

import numpy as np

from repro.configs.base import RunConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()

    run_cfg = RunConfig(
        learning_rate=1e-3, warmup_steps=20, total_steps=args.steps,
        optimizer="adamw_dd",          # df32 master weights: paper's engine
        policy={},                     # set {"lm_head": "dd"} for dd logits
    )
    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train(args.arch, steps=args.steps, batch=args.batch,
                    seq=args.seq, reduce=True, ckpt_dir=ckpt_dir,
                    run_cfg=run_cfg, log_every=20,
                    inject_failure_at=args.steps // 2)
    losses = out["losses"]
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nfailures recovered: {out['failures']}")
    print(f"loss {first:.3f} -> {last:.3f} ({(1 - last / first) * 100:.0f}% down)")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
