"""Fault-tolerant checkpointing with elastic restore."""

from .ckpt import CheckpointManager, restore_resharded, save_pytree, load_pytree  # noqa: F401
