"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step:  <dir>/step_<n>/  leaf files 'p<k>.npy' + 'meta.json'
(tree structure, step, logical axes).  Writes go to a tmp dir and are
renamed into place (atomic on POSIX), so a crash mid-save never corrupts
the latest checkpoint.  Saves can run on a background thread (async) —
the train loop donates a host copy and keeps stepping.

Elastic restore: leaves are loaded as host arrays and ``jax.device_put``
onto the *target* mesh's NamedShardings (derived from the same logical-axis
rules), so a checkpoint written on a 16x16 mesh restores onto 2x16x16,
4x4, or a single device unchanged (test_checkpoint.py exercises mesh
changes).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "restore_resharded", "CheckpointManager"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree, directory: str, step: int, extra: Optional[dict] = None):
    """Atomic synchronous save."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        paths, leaves, _ = _flatten_with_paths(tree)
        meta = {"step": step, "paths": paths, "extra": extra or {}}
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"p{i}.npy"), np.asarray(leaf))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def load_pytree(template, directory: str, step: Optional[int] = None):
    """Load into the structure of `template` (host numpy leaves)."""
    step_dir = latest_step_dir(directory) if step is None else \
        os.path.join(directory, f"step_{step:08d}")
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    with open(os.path.join(step_dir, "meta.json")) as f:
        meta = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(template)
    loaded = [np.load(os.path.join(step_dir, f"p{i}.npy"))
              for i in range(len(leaves))]
    return jax.tree_util.tree_unflatten(treedef, loaded), meta


def latest_step_dir(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    return os.path.join(directory, steps[-1]) if steps else None


def latest_step(directory: str) -> Optional[int]:
    d = latest_step_dir(directory)
    return int(d.rsplit("_", 1)[1]) if d else None


def restore_resharded(template, directory: str, shardings=None,
                      step: Optional[int] = None):
    """Load + device_put onto target shardings (elastic re-mesh restore)."""
    host_tree, meta = load_pytree(template, directory, step)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, host_tree), meta
    put = jax.tree.map(
        lambda x, s: jax.device_put(x, s), host_tree, shardings)
    return put, meta


class CheckpointManager:
    """Keep-last-k manager with optional async (background-thread) saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, tree, step: int, extra: Optional[dict] = None):
        self.wait()  # one in-flight save at a time
        # snapshot to host BEFORE returning control (donation-safe)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _do():
            try:
                save_pytree(host_tree, self.directory, step, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()
            self.wait()

    def restore(self, template, shardings=None, step: Optional[int] = None):
        self.wait()
        return restore_resharded(template, self.directory, shardings, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
