"""Arch configs: one module per assigned architecture + shape specs."""

from .base import ArchConfig, RunConfig, get_config, list_configs, register  # noqa: F401
from .shapes import SHAPES, ShapeSpec, runnable_cells, skip_reason  # noqa: F401
