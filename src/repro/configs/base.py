"""Architecture + run configuration dataclasses and the registry."""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "RunConfig", "register", "get_config", "list_configs"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0        # xlstm: every k-th layer is sLSTM
    attn_every: int = 0         # hybrid: shared attn block every k mamba blocks
    # vlm
    cross_attn_every: int = 0
    n_modality_tokens: int = 0  # stub frontend sequence length
    # audio / encoder-only
    encoder_only: bool = False
    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token context (per spec: ssm/hybrid only)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp_dense = 3 * d * self.d_ff
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + mlp_dense + 2 * d
            total = self.n_layers * per_layer
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                total += n_cross * (attn + d)
        elif self.family == "moe":
            moe = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
            shared = 3 * d * self.d_ff * self.n_shared_experts
            per_layer = attn + moe + shared + 2 * d
            total = self.n_layers * per_layer
        elif self.family == "ssm":
            d_inner = self.ssm_expand * d
            mlstm = d * d_inner * 3 + d_inner * d + d_inner * 3  # q,k,v,out,gates
            per_layer = mlstm + 2 * d
            total = self.n_layers * per_layer
        elif self.family == "hybrid":
            d_inner = self.ssm_expand * d
            mamba = d * (2 * d_inner + 2 * self.ssm_state + self.n_heads) \
                + d_inner * d + d_inner * self.ssm_conv
            n_attn = self.n_layers // max(self.attn_every, 1)
            total = self.n_layers * (mamba + 2 * d) + (attn + mlp_dense + 2 * d)
        else:
            total = self.n_layers * (attn + mlp_dense + 2 * d)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        active_moe_frac = (self.experts_per_token + self.n_shared_experts) \
            / max(self.n_experts + self.n_shared_experts, 1)
        moe_params = 3 * d * self.d_ff * (self.n_experts + self.n_shared_experts) \
            * self.n_layers
        return int(self.param_count() - moe_params * (1 - active_moe_frac))


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run settings (everything not architectural)."""

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    remat: str = "none"              # none | full | dots
    optimizer: str = "adamw"         # adamw | adamw_int8 | adamw_dd
    grad_compression: str = "none"   # none | int8_ef
    compensated_psum: bool = False   # DD-compensated gradient reduction
    policy: dict = dataclasses.field(default_factory=dict)
    seed: int = 0


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        from . import registry  # noqa: F401  (populate)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from . import registry  # noqa: F401

    return sorted(_REGISTRY)
