"""hubert-xlarge [audio] — encoder-only, w2v2-style backbone.
[arXiv:2106.07447; unverified]

Frame frontend is a STUB per the task spec: input_specs() supplies
precomputed frame embeddings; training is masked-unit prediction over the
504-unit codebook.  Encoder-only => no decode shapes.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    encoder_only=True, n_modality_tokens=0,
    source="arXiv:2106.07447",
))
