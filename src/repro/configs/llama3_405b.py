"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    rope_theta=5e5,
    source="arXiv:2407.21783",
))
