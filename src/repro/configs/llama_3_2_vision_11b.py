"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the task spec: input_specs() supplies
precomputed patch embeddings (n_modality_tokens, d_model).
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, n_modality_tokens=1024,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
