"""Import every arch config module to populate the registry."""

from . import (  # noqa: F401
    hubert_xlarge,
    llama3_405b,
    llama_3_2_vision_11b,
    mistral_nemo_12b,
    moonshot_v1_16b_a3b,
    qwen3_0_6b,
    qwen3_4b,
    qwen3_moe_235b_a22b,
    xlstm_350m,
    zamba2_2_7b,
)

ALL_ARCHS = [
    "qwen3-4b",
    "mistral-nemo-12b",
    "qwen3-0.6b",
    "llama3-405b",
    "xlstm-350m",
    "zamba2-2.7b",
    "moonshot-v1-16b-a3b",
    "qwen3-moe-235b-a22b",
    "llama-3.2-vision-11b",
    "hubert-xlarge",
]
