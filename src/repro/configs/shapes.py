"""The four assigned input-shape sets (LM-family, per the task spec)."""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "runnable_cells", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg, shape: ShapeSpec) -> str | None:
    """None if (arch, shape) is runnable; else the DESIGN.md skip reason."""
    if cfg.encoder_only and shape.kind == "decode":
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return None


def runnable_cells(configs, shapes=None):
    shapes = shapes or list(SHAPES.values())
    cells = []
    for cfg in configs:
        for sh in shapes:
            if skip_reason(cfg, sh) is None:
                cells.append((cfg, sh))
    return cells
