"""xlstm-350m [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

d_ff=0 per spec: the recurrent blocks carry their own up/down projections
(expand factor 2); every 4th layer is an sLSTM block, the rest mLSTM.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    ssm_expand=2, slstm_every=4,
    source="arXiv:2405.04517",
))
