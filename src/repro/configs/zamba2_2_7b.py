"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks. [arXiv:2411.15242; hf]

54 Mamba2 layers with ONE shared transformer block applied every 6 layers
(9 applications, shared parameters), kv=32 => MHA in the shared block.
"""

from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, attn_every=6,
    source="arXiv:2411.15242",
))
