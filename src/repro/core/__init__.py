"""Core numerics: the paper's contribution (binary128-class GEMM) in JAX.

Extended precision requires f64 limb support on the host path; enable x64
once at import.  Model code (src/repro/models) always passes explicit dtypes
and is unaffected (weak-typed python scalars keep array dtypes).
"""

import jax

jax.config.update("jax_enable_x64", True)

from . import dd, efts, mp, qd, td  # noqa: E402,F401
