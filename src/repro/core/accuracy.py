"""Accuracy regression oracle: exact-rational Hilbert-matrix GEMM.

The paper validates its FPGA GEMM against a CPU Rgemm reference (Eq. 6);
here each precision tier is validated against an *exact* reference instead.
The Hilbert matrix H_ij = 1/(i+j+1) (maximally ill-conditioned, the classic
extended-precision stress case) is formed IN the tier's own arithmetic — a
multi-limb division, so every limb carries signal and the product genuinely
rounds at the tier's precision — and H @ H is then evaluated in exact
rational arithmetic (``fractions.Fraction``) over those representable
multi-limb entries.  The observed relative error of each tier's engine
output against that oracle is the quantity the regression gate pins:

    dd (2 limbs, ~106-bit)  must stay <= 2^-100
    td (3 limbs, ~159-bit)  must stay <= 2^-150
    qd (4 limbs, ~212-bit)  must stay <= 2^-190

``benchmarks/bench_accuracy.py`` emits the same numbers to
``BENCH_ACCURACY.json`` (uploaded by CI) so the accuracy trajectory is
machine-readable across commits; tests/test_accuracy_gate.py asserts the
thresholds in tier 1.
"""

from __future__ import annotations

import functools
import json
import time
from fractions import Fraction

import jax.numpy as jnp
import numpy as np

from . import mp

__all__ = ["GATES", "GATED_BACKENDS", "hilbert_f64",
           "hilbert_relative_error", "accuracy_report",
           "write_accuracy_json", "max_rel_err",
           "frac_matrix", "frac_matmul", "frac_sub", "frac_max_abs"]

# per-tier observed-relative-error ceilings (the regression gate).  The
# expected error class is a few ulp of the tier (2^-104 / 2^-155 / 2^-206
# for dd / td / qd); each gate leaves a handful of bits of headroom so the
# gate trips on real regressions, not on reduction-order jitter.
GATES = {"dd": 2.0 ** -100, "td": 2.0 ** -150, "qd": 2.0 ** -190}

# backends pinned by the gate, with the tiers each one supports: the
# engine default (xla) plus both Ozaki slicing paths — the whole-K
# diagonal-grouped XLA recombination (dd/td; qd is planner-rejected) and
# the per-slab fused Pallas kernel (every tier)
GATED_BACKENDS = {
    "xla": ("dd", "td", "qd"),
    "ozaki": ("dd", "td"),
    "ozaki-pallas": ("dd", "td", "qd"),
}


def hilbert_f64(n: int) -> np.ndarray:
    """Hilbert matrix H_ij = 1/(i+j+1), rounded once to f64."""
    i = np.arange(n, dtype=np.float64)
    return 1.0 / (i[:, None] + i[None, :] + 1.0)


def max_rel_err(got, want) -> float:
    """Max |got - want| / max(1, max|want|), measured in the values' tier.

    The shared conformance metric: the smoke benchmark, the conformance
    matrix, and the kernel tests all gate on this one definition.
    """
    diff = np.abs(np.asarray(mp.to_float(mp.sub(got, want)), np.float64))
    scale = max(1.0, float(np.abs(np.asarray(mp.to_float(want))).max()))
    return float(diff.max()) / scale


def hilbert_tier(precision: str, n: int):
    """Hilbert matrix formed in tier arithmetic: every limb carries signal."""
    i = jnp.arange(n, dtype=jnp.float64)
    denom = i[:, None] + i[None, :] + 1.0
    one = mp.from_float(jnp.ones((n, n)), precision)
    return mp.div(one, mp.from_float(denom, precision))


def _frac(limbs_np, i: int, j: int) -> Fraction:
    return sum((Fraction(float(l[i, j])) for l in limbs_np), Fraction(0))


# -- exact-rational matrix helpers (the LAPACK-grade residual gates) --------
#
# A multi-limb value is a finite sum of binary floats, hence an exact
# rational; residuals like PA - LU measured over Fractions carry zero
# measurement noise, so the test gates in tests/test_linalg_gates.py pin
# the factorization's *own* backward error and nothing else.


def frac_matrix(x):
    """Exact rational entries of a 2-D multi-limb value."""
    ls = [np.asarray(l, np.float64) for l in mp.limbs(x)]
    m, n = ls[0].shape
    return [[_frac(ls, i, j) for j in range(n)] for i in range(m)]


def frac_matmul(fa, fb):
    """Exact rational product of two Fraction matrices."""
    inner = len(fb)
    cols = len(fb[0])
    return [[sum((fa[i][k] * fb[k][j] for k in range(inner)), Fraction(0))
             for j in range(cols)] for i in range(len(fa))]


def frac_sub(fa, fb):
    return [[x - y for x, y in zip(ra, rb)] for ra, rb in zip(fa, fb)]


def frac_max_abs(f) -> float:
    """max |entry| of a Fraction matrix, rounded once to f64 at the end."""
    return float(max(abs(e) for row in f for e in row))


@functools.lru_cache(maxsize=8)
def _hilbert_oracle(precision: str, n: int):
    """Exact rational H @ H over the tier's representable H entries.

    Depends only on (precision, n) — NOT on the backend under test — and
    the O(n^3) Fraction arithmetic dominates gate wall time, so it is
    computed once and shared by every gated backend's cell.
    """
    x = hilbert_tier(precision, n)
    in_limbs = [np.asarray(l, np.float64) for l in mp.limbs(x)]
    fx = [[_frac(in_limbs, i, j) for j in range(n)] for i in range(n)]
    return [[sum((fx[i][k] * fx[k][j] for k in range(n)), Fraction(0))
             for j in range(n)] for i in range(n)]


def hilbert_relative_error(precision: str = "dd", n: int = 16,
                           backend: str = "xla") -> float:
    """Max observed relative error of one engine tier on H @ H vs the exact
    rational product of the tier's own (representable) H entries."""
    from repro.gemm import matmul

    x = hilbert_tier(precision, n)
    got = matmul(x, x, backend=backend)
    out_limbs = [np.asarray(l, np.float64) for l in mp.limbs(got)]
    want = _hilbert_oracle(precision, n)
    worst = 0.0
    for i in range(n):
        for j in range(n):
            rel = abs(float((_frac(out_limbs, i, j) - want[i][j])
                            / want[i][j]))
            worst = max(worst, rel)
    return worst


def accuracy_report(n: int = 16, backend: str = "xla",
                    tiers=None) -> dict:
    """Observed relative error per tier, with its gate and headroom."""
    out = {}
    for prec in (tiers if tiers is not None else GATES):
        gate = GATES[prec]
        err = hilbert_relative_error(prec, n=n, backend=backend)
        out[prec] = {
            "rel_err": err,
            "gate": gate,
            "log2_err": float(np.log2(err)) if err > 0 else None,
            "passes": bool(err <= gate),
        }
    return out


def write_accuracy_json(path: str, n: int = 16, backend: str = "xla") -> dict:
    """Emit the per-tier accuracy artifact (schema repro-accuracy/v2).

    ``tiers`` keeps the primary backend's per-tier rows (the v1 layout);
    ``backends`` adds one such block per gated backend, so a slicing-path
    regression is visible in the artifact even when the default engine
    path still passes.
    """
    import jax

    backends = {
        be: accuracy_report(n=n, backend=be, tiers=supported)
        for be, supported in GATED_BACKENDS.items()
    }
    # the legacy per-tier block aliases the primary backend's rows when it
    # is gated with the full tier set (the common case); a partially-gated
    # primary (e.g. dd-only ozaki) reports only the tiers it supports
    tiers = backends[backend] \
        if set(GATED_BACKENDS.get(backend, ())) == set(GATES) \
        else accuracy_report(n=n, backend=backend,
                             tiers=GATED_BACKENDS.get(backend))
    doc = {
        "schema": "repro-accuracy/v2",
        "unix_time": time.time(),
        "platform": jax.default_backend(),
        "case": {"matrix": "hilbert", "n": n, "backend": backend,
                 "backends": sorted(GATED_BACKENDS)},
        "tiers": tiers,
        "backends": backends,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc
