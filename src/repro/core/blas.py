"""Rgemm-compatible BLAS layer (paper §III-A, Listing 1).

Mirrors MPLAPACK's ``Rgemm`` split exactly as the paper implements it: the
accelerator computes only ``C' = A @ B`` (Eq. 2); the host handles transposes
and the alpha/beta epilogue (Eq. 1), because scalar-matrix multiply and
matrix add are O(n^2) and "very costly in a GEMM design on an FPGA" — and
equally pointless to fuse into the TPU kernel.

All matrices are ``dd.DD`` struct-of-arrays; ``alpha``/``beta`` may be python
floats or DD scalars.

The accelerator product routes through the unified execution engine
(``repro.gemm``): pass a prebuilt ``GemmPlan`` via ``plan=`` to pin every
dispatch decision, or keyword overrides (``backend=``, ``mesh=``, block
shapes) that feed the planner; with neither, the engine plans from shape,
platform, and the tuned-block cache.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.gemm import matmul

from . import dd

__all__ = ["rgemm", "rsyrk", "transpose", "identity"]


def transpose(a: dd.DD) -> dd.DD:
    # swap the matrix axes only, so 't' flags compose with the engine's
    # batched operands ((..., m, k) -> (..., k, m)); equals .T for 2-D
    return dd.DD(jnp.swapaxes(a.hi, -1, -2), jnp.swapaxes(a.lo, -1, -2))


def identity(n: int, dtype=jnp.float64) -> dd.DD:
    return dd.from_float(jnp.eye(n, dtype=dtype))


def _as_dd_scalar(x, dtype) -> dd.DD:
    if isinstance(x, dd.DD):
        return x
    return dd.from_float(jnp.asarray(x, dtype=dtype))


def rgemm(transa: str, transb: str, alpha, a: dd.DD, b: dd.DD, beta,
          c: dd.DD | None = None, *, plan=None, **plan_overrides) -> dd.DD:
    """C = alpha * op(A) @ op(B) + beta * C   (op per 'n'/'t' flags).

    The m/n/k/ld* arguments of the C API are implied by array shapes here;
    the transpose and epilogue happen on the host side of the split, the
    O(mnk) product on the engine-planned accelerator path.
    """
    if transa.lower().startswith("t"):
        a = transpose(a)
    if transb.lower().startswith("t"):
        b = transpose(b)
    prod = matmul(a, b, plan=plan, **plan_overrides)
    alpha = _as_dd_scalar(alpha, prod.hi.dtype)
    out = dd.mul(dd.DD(jnp.broadcast_to(alpha.hi, prod.shape),
                       jnp.broadcast_to(alpha.lo, prod.shape)), prod)
    if c is not None:
        beta = _as_dd_scalar(beta, prod.hi.dtype)
        bc = dd.mul(dd.DD(jnp.broadcast_to(beta.hi, c.shape),
                          jnp.broadcast_to(beta.lo, c.shape)), c)
        out = dd.add(out, bc)
    return out


def rsyrk(uplo: str, trans: str, alpha, a: dd.DD, beta,
          c: dd.DD | None = None, **kwargs) -> dd.DD:
    """C = alpha * A @ A^T + beta * C (symmetric rank-k update, full form).

    SDPA's PDIPM calls this shape constantly; we form the full symmetric
    result (uplo kept for API compatibility).
    """
    del uplo
    at = transpose(a)
    if trans.lower().startswith("t"):
        return rgemm("n", "n", alpha, at, a, beta, c, **kwargs)
    return rgemm("n", "n", alpha, a, at, beta, c, **kwargs)
