"""Rgemm-compatible BLAS layer (paper §III-A, Listing 1).

Mirrors MPLAPACK's ``Rgemm`` split: the accelerator computes the O(mnk)
product ``C' = A @ B`` (Eq. 2); this layer handles the transposes, and
hands the alpha/beta epilogue (Eq. 1) to the engine.  The paper keeps the
epilogue on the host because scalar-matrix multiply is "very costly in a
GEMM design on an FPGA"; on the TPU port the engine instead *fuses* it
into the drain step of epilogue-capable kernels (the ``ozaki-pallas``
backend applies alpha/beta while the C' tile is still in VMEM) and falls
back to an identical tier-arithmetic post-step everywhere else.

Matrices are multi-limb struct-of-arrays values — ``dd.DD`` (binary128
class) or ``qd.QD`` (binary128+ class); the epilogue runs in the operands'
own tier.  ``alpha``/``beta`` may be python floats or multi-limb scalars
of either tier (promoted to match the product).

The product routes through the unified execution engine (``repro.gemm``):
pass a prebuilt ``GemmPlan`` via ``plan=`` to pin every dispatch decision,
or keyword overrides (``backend=``, ``mesh=`` — with an optional
``shard_axis``/``shard_axis_n``/``k_panel`` shard spec for the 2-D SUMMA
distribution — block shapes) that feed the planner; with neither, the
engine plans from shape, precision, platform, and the tuned-block cache.
``rsyrk``'s SDP-shaped calls and batched operands compose with the mesh
in one engine call.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.gemm import matmul

from . import mp

__all__ = ["rgemm", "rsyrk", "transpose", "identity", "rlange"]


def transpose(a):
    # swap the matrix axes only, so 't' flags compose with the engine's
    # batched operands ((..., m, k) -> (..., k, m)); equals .T for 2-D
    return mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), a)


def identity(n: int, dtype=jnp.float64, precision: str = "dd"):
    return mp.from_float(jnp.eye(n, dtype=dtype), precision)


def rlange(norm: str, a):
    """Matrix norm of a multi-limb value (MPLAPACK's Rlange), as f64.

    ``norm``: ``'m'`` max |a_ij|, ``'1'`` max column sum, ``'i'`` max row
    sum (the infinity norm the refinement solver's backward-error metric
    uses).  The row/column sums are accumulated in the value's own tier;
    only the final scalar rounds to f64, so ill-scaled matrices do not
    lose their small entries to f64 accumulation.  Traceable (returns a
    jnp scalar), so the solver's convergence metrics stay inside one jit.
    """
    kind = norm.lower()
    if kind == "m":
        return mp.max_abs(a)
    if kind not in ("1", "i", "inf"):
        raise ValueError(f"unknown norm {norm!r}; one of 'm', '1', 'i'")
    axis = -2 if kind == "1" else -1
    sums = mp.sum_(mp.abs_(a), axis=axis)
    return jnp.max(mp.limbs(sums)[0])


def rgemm(transa: str, transb: str, alpha, a, b, beta,
          c=None, *, plan=None, **plan_overrides):
    """C = alpha * op(A) @ op(B) + beta * C   (op per 'n'/'t' flags).

    The m/n/k/ld* arguments of the C API are implied by array shapes here;
    the transposes happen on the host side of the split, the O(mnk)
    product AND the epilogue on the engine-planned accelerator path (which
    fuses alpha/beta into the kernel drain when the backend supports it).
    """
    if transa.lower().startswith("t"):
        a = transpose(a)
    if transb.lower().startswith("t"):
        b = transpose(b)
    return matmul(a, b, plan=plan, alpha=alpha, beta=beta, c=c,
                  **plan_overrides)


def rsyrk(uplo: str, trans: str, alpha, a, beta,
          c=None, **kwargs):
    """C = alpha * A @ A^T + beta * C (symmetric rank-k update, full form).

    SDPA's PDIPM calls this shape constantly; we form the full symmetric
    result (uplo kept for API compatibility).
    """
    del uplo
    at = transpose(a)
    if trans.lower().startswith("t"):
        return rgemm("n", "n", alpha, at, a, beta, c, **kwargs)
    return rgemm("n", "n", alpha, a, at, beta, c, **kwargs)
