"""Rgemm-compatible BLAS layer (paper §III-A, Listing 1).

Mirrors MPLAPACK's ``Rgemm`` split exactly as the paper implements it: the
accelerator computes only ``C' = A @ B`` (Eq. 2); the host handles transposes
and the alpha/beta epilogue (Eq. 1), because scalar-matrix multiply and
matrix add are O(n^2) and "very costly in a GEMM design on an FPGA" — and
equally pointless to fuse into the TPU kernel.

Matrices are multi-limb struct-of-arrays values — ``dd.DD`` (binary128
class) or ``qd.QD`` (binary128+ class); the epilogue runs in the operands'
own tier via ``core.mp``.  ``alpha``/``beta`` may be python floats or
multi-limb scalars of either tier (promoted to match the product).

The accelerator product routes through the unified execution engine
(``repro.gemm``): pass a prebuilt ``GemmPlan`` via ``plan=`` to pin every
dispatch decision, or keyword overrides (``backend=``, ``mesh=``, block
shapes) that feed the planner; with neither, the engine plans from shape,
precision, platform, and the tuned-block cache.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.gemm import matmul

from . import mp

__all__ = ["rgemm", "rsyrk", "transpose", "identity"]


def transpose(a):
    # swap the matrix axes only, so 't' flags compose with the engine's
    # batched operands ((..., m, k) -> (..., k, m)); equals .T for 2-D
    return mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), a)


def identity(n: int, dtype=jnp.float64, precision: str = "dd"):
    return mp.from_float(jnp.eye(n, dtype=dtype), precision)


def _as_scalar(x, like):
    """Coerce a python float / multi-limb scalar to ``like``'s tier."""
    prec = mp.precision_of(like)
    try:
        return mp.promote(x, prec)
    except TypeError:
        return mp.from_float(jnp.asarray(x, like.limbs()[0].dtype), prec)


def rgemm(transa: str, transb: str, alpha, a, b, beta,
          c=None, *, plan=None, **plan_overrides):
    """C = alpha * op(A) @ op(B) + beta * C   (op per 'n'/'t' flags).

    The m/n/k/ld* arguments of the C API are implied by array shapes here;
    the transpose and epilogue happen on the host side of the split, the
    O(mnk) product on the engine-planned accelerator path.
    """
    if transa.lower().startswith("t"):
        a = transpose(a)
    if transb.lower().startswith("t"):
        b = transpose(b)
    prod = matmul(a, b, plan=plan, **plan_overrides)
    alpha = _as_scalar(alpha, prod)
    out = mp.mul(mp.broadcast_to(alpha, prod.shape), prod)
    if c is not None:
        beta = _as_scalar(beta, prod)
        bc = mp.mul(mp.broadcast_to(beta, c.shape), c)
        out = mp.add(out, bc)
    return out


def rsyrk(uplo: str, trans: str, alpha, a, beta,
          c=None, **kwargs):
    """C = alpha * A @ A^T + beta * C (symmetric rank-k update, full form).

    SDPA's PDIPM calls this shape constantly; we form the full symmetric
    result (uplo kept for API compatibility).
    """
    del uplo
    at = transpose(a)
    if trans.lower().startswith("t"):
        return rgemm("n", "n", alpha, at, a, beta, c, **kwargs)
    return rgemm("n", "n", alpha, a, at, beta, c, **kwargs)
