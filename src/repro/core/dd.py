"""Double-word ("double-double") arithmetic — the binary128-class MAC.

A ``DD`` value is an unevaluated sum ``hi + lo`` of two native floats with
``|lo| <= ulp(hi)/2``.  Over f64 limbs this gives ~106 mantissa bits
("dd64", the classic double-double used by the paper's own related work
[Nakasato 2011, SDPA-DD, Kouya 2021]); over f32 limbs ~49 bits ("df32"),
the TPU-VPU-native format.  binary128 proper has 113 bits: dd64 sits 7 bits
short, qd (see qd.py) and the Ozaki path (ozaki.py) overshoot it.  The
accuracy delta is quantified in benchmarks/bench_accuracy.py.

Representation is struct-of-arrays: ``DD(hi, lo)`` where hi/lo are equal-shape
jnp arrays, so every DD op is a vectorized multiply-add "unit" in the paper's
sense.  Algorithms are the standard accurate variants (Dekker/Knuth/
Hida-Li-Bailey); each op's exactness/error bound is property-tested against
``fractions.Fraction`` oracles in tests/test_dd.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .efts import quick_two_sum, two_prod, two_sum

__all__ = [
    "DD",
    "dd",
    "from_float",
    "from_hi_lo",
    "to_float",
    "zeros",
    "add",
    "sub",
    "neg",
    "abs_",
    "mul",
    "mul_pow2",
    "fma",
    "div",
    "sqrt",
    "sum_",
    "dot",
    "lt",
    "le",
    "gt",
    "ge",
    "where",
    "eps",
]


class DD(NamedTuple):
    """Unevaluated sum hi + lo. Leaves are jnp arrays (any shape)."""

    hi: jnp.ndarray
    lo: jnp.ndarray

    @property
    def dtype(self):
        return self.hi.dtype

    @property
    def shape(self):
        return self.hi.shape

    def astype(self, dtype):
        # narrowing conversions renormalize through the target precision
        hi = self.hi.astype(dtype)
        lo = (self.hi - hi.astype(self.hi.dtype)).astype(dtype) + self.lo.astype(dtype)
        return DD(*quick_two_sum(hi, lo))

    def __getitem__(self, idx):
        return DD(self.hi[idx], self.lo[idx])

    def reshape(self, *shape):
        return DD(self.hi.reshape(*shape), self.lo.reshape(*shape))

    def limbs(self):
        """Limb list, most-significant first (multi-limb-generic protocol)."""
        return [self.hi, self.lo]


def eps(dtype) -> float:
    """Unit roundoff of the DD format with the given limb dtype."""
    p = 53 if jnp.dtype(dtype) == jnp.float64 else 24
    return 2.0 ** (-2 * p)


def from_float(x, dtype=None) -> DD:
    x = jnp.asarray(x, dtype=dtype)
    return DD(x, jnp.zeros_like(x))


def from_hi_lo(hi, lo) -> DD:
    """Renormalize an arbitrary (hi, lo) pair into canonical DD form."""
    return DD(*two_sum(hi, lo))


def dd(x, dtype=jnp.float64) -> DD:
    """Coerce scalars/arrays/DD to DD."""
    if isinstance(x, DD):
        return x
    return from_float(x, dtype=dtype)


def to_float(x: DD):
    return x.hi + x.lo


def zeros(shape, dtype=jnp.float64) -> DD:
    z = jnp.zeros(shape, dtype=dtype)
    return DD(z, z)


def neg(a: DD) -> DD:
    return DD(-a.hi, -a.lo)


def abs_(a: DD) -> DD:
    m = a.hi < 0
    return DD(jnp.where(m, -a.hi, a.hi), jnp.where(m, -a.lo, a.lo))


def add(a: DD, b: DD) -> DD:
    """Accurate DD + DD (Li et al. "IEEE add"; error <= 3 ulp^2)."""
    s, e = two_sum(a.hi, b.hi)
    t, f = two_sum(a.lo, b.lo)
    e = e + t
    s, e = quick_two_sum(s, e)
    e = e + f
    return DD(*quick_two_sum(s, e))


def sub(a: DD, b: DD) -> DD:
    return add(a, neg(b))


def add_float(a: DD, b) -> DD:
    s, e = two_sum(a.hi, b)
    e = e + a.lo
    return DD(*quick_two_sum(s, e))


def mul(a: DD, b: DD) -> DD:
    """DD * DD (error <= 4 ulp^2)."""
    p, e = two_prod(a.hi, b.hi)
    e = e + (a.hi * b.lo + a.lo * b.hi)
    return DD(*quick_two_sum(p, e))


def mul_float(a: DD, b) -> DD:
    p, e = two_prod(a.hi, b)
    e = e + a.lo * b
    return DD(*quick_two_sum(p, e))


def mul_pow2(a: DD, s) -> DD:
    """Exact scaling by a power of two."""
    return DD(a.hi * s, a.lo * s)


def fma(acc: DD, a: DD, b: DD) -> DD:
    """acc + a*b — the binary128-class multiply-add "PE" operation.

    This is the exact op the paper instantiates P_R x P_C times; one call is
    ~86 native flops (measured in benchmarks/bench_tile.py), which sets the
    F_peak model for the TPU port.
    """
    return add(acc, mul(a, b))


def div(a: DD, b: DD) -> DD:
    """Long-division style DD / DD (QD library algorithm)."""
    q1 = a.hi / b.hi
    r = sub(a, mul_float(b, q1))
    q2 = r.hi / b.hi
    r = sub(r, mul_float(b, q2))
    q3 = r.hi / b.hi
    q, e = quick_two_sum(q1, q2)
    return add_float(DD(q, e), q3)


def sqrt(a: DD) -> DD:
    """DD sqrt via Karp's trick: x ~ 1/sqrt(hi); s = a*x; s + x*(a - s^2)/2."""
    x = 1.0 / jnp.sqrt(a.hi)
    ax = a.hi * x
    ax_dd = from_float(ax)
    err = sub(a, mul(ax_dd, ax_dd))
    res = add_float(err, 0.0)
    corr = res.hi * (x * 0.5)
    out = add_float(ax_dd, corr)
    # guard zero (sqrt(0) -> 0, avoid inf * 0 = nan)
    zero = a.hi == 0
    return DD(jnp.where(zero, 0.0, out.hi), jnp.where(zero, 0.0, out.lo))


def sum_(a: DD, axis=None, keepdims=False) -> DD:
    """Compensated reduction of a DD array along an axis (pairwise-free,

    sequential two_sum chain via a Python loop over a moved axis is too slow;
    instead reduce with repeated halving which keeps every partial in DD).
    """
    if axis is None:
        flat = DD(a.hi.reshape(-1), a.lo.reshape(-1))
        return sum_(flat, axis=0, keepdims=keepdims)
    n = a.hi.shape[axis]
    hi = jnp.moveaxis(a.hi, axis, 0)
    lo = jnp.moveaxis(a.lo, axis, 0)
    cur = DD(hi, lo)
    m = n
    while m > 1:
        half = m // 2
        even = DD(cur.hi[: 2 * half : 2], cur.lo[: 2 * half : 2])
        odd = DD(cur.hi[1 : 2 * half : 2], cur.lo[1 : 2 * half : 2])
        red = add(even, odd)
        if m % 2:
            red = add(
                red,
                DD(
                    jnp.concatenate([cur.hi[-1:], jnp.zeros_like(red.hi[1:])], 0),
                    jnp.concatenate([cur.lo[-1:], jnp.zeros_like(red.lo[1:])], 0),
                ),
            )
        cur = red
        m = half
    out = DD(cur.hi[0], cur.lo[0])
    if keepdims:
        out = DD(jnp.expand_dims(out.hi, axis), jnp.expand_dims(out.lo, axis))
    return out


def dot(a: DD, b: DD) -> DD:
    """Inner product of two DD vectors with DD accumulation."""
    return sum_(mul(a, b), axis=0)


def lt(a: DD, b: DD):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo < b.lo))


def le(a: DD, b: DD):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo <= b.lo))


def gt(a: DD, b: DD):
    return lt(b, a)


def ge(a: DD, b: DD):
    return le(b, a)


def where(c, a: DD, b: DD) -> DD:
    return DD(jnp.where(c, a.hi, b.hi), jnp.where(c, a.lo, b.lo))
