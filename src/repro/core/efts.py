"""Error-free transformations (EFTs) — the primitive "DSP blocks" of this port.

The paper composes binary128 multiply-add units out of FPGA DSP blocks.  On a
TPU the native units are f32 (VPU lanes) and bf16 (MXU); we compose wide
arithmetic out of them with error-free transformations:

  two_sum(a, b)        -> (s, e)  with  s = fl(a+b),  s + e == a + b  exactly
  quick_two_sum(a, b)  -> same, requires |a| >= |b| (3 ops instead of 6)
  two_prod(a, b)       -> (p, e)  with  p + e == a * b * (1 + eps_tp)

Compiler-safety design note (important, discovered empirically):
XLA:CPU's LLVM backend performs FMA *contraction* — a float multiply feeding
an add/subtract inside one fused loop may be emitted as a single fma, so the
add sees the UNROUNDED product.  Classic Dekker two_prod subtracts the
rounded ``p = fl(a*b)`` from partial products; if the compiler rematerializes
``a*b`` into that subtraction as an fma, the error term collapses.  The
implementation below is **contraction-robust by construction**:

  * the operand split uses integer mantissa masking (no float multiply, so
    nothing to contract; Veltkamp's ``C*a`` trick is itself contractible);
  * ``p`` is assembled from the four *exact* partial products with two_sum
    chains — every multiply that reaches an add is exactly representable, so
    fma contraction cannot change any value.

Cost: two_prod is no longer bit-exact; its relative error is <= ~2^-2p+2
(2^-105 for f64 limbs, 2^-47 for f32), from (a) rounding when summing the
three two_sum error terms and (b) the lowest partial product carrying
p+1 bits under the mask split (no Veltkamp sign trick).  Double-word
arithmetic built on it keeps relative error ~2^-104 / ~2^-46 — the same
class as the binary128 target (and as the paper's own DD-based related
work).  Property tests pin these bounds against Fraction oracles.

All algorithms assume round-to-nearest and flush-to-zero-free inputs in the
normal range (XLA:CPU flushes subnormals).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "two_sum",
    "quick_two_sum",
    "mask_split",
    "two_prod",
    "two_prod_terms",
    "two_prod_exact",
    "TWO_PROD_RELERR",
]

# relative error bound of two_prod per limb dtype (see module docstring)
TWO_PROD_RELERR = {
    jnp.dtype(jnp.float64): 2.0**-104,
    jnp.dtype(jnp.float32): 2.0**-46,
}


def two_sum(a, b):
    """Knuth's branch-free exact addition: s + e == a + b exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def quick_two_sum(a, b):
    """Dekker's fast exact addition. Exact when |a| >= |b| (or a == 0)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _mask_for(dtype):
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        # clear low 27 of 52 explicit mantissa bits -> hi has 26 bits
        return jnp.uint64(0xFFFFFFFFF8000000), jnp.uint64
    if dtype == jnp.float32:
        # clear low 12 of 23 explicit mantissa bits -> hi has 12 bits
        return jnp.uint32(0xFFFFF000), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def mask_split(a):
    """Split a == hi + lo exactly by masking low mantissa bits (integer ops).

    hi keeps the top ~p/2 mantissa bits; lo = a - hi is exact because hi and
    a share sign/exponent and agree on high bits (Sterbenz).  Unlike the
    Veltkamp split there is no float multiply for the compiler to contract.
    """
    mask, uint = _mask_for(a.dtype)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    lo = a - hi
    return hi, lo


def two_prod(a, b):
    """Near-exact multiplication: p + e == a*b up to TWO_PROD_RELERR[dtype].

    The four partial products of the mask splits are (near-)exactly
    representable, so assembling them with two_sum chains is immune to fma
    contraction (see module docstring).  ``p`` is within 1 ulp of fl(a*b).
    """
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    m1 = ah * bh  # exact
    m2 = ah * bl  # exact
    m3 = al * bh  # exact
    m4 = al * bl  # <= 1/2 ulp error at 2^-(2p+2)|ab| scale (p+1-bit operands)
    s, e1 = two_sum(m1, m2)
    s, e2 = two_sum(s, m3)
    s, e3 = two_sum(s, m4)
    e = e1 + (e2 + e3)
    return s, e


def _mask_keep(dtype, keep: int):
    """Mask clearing all but the top ``keep`` explicit mantissa bits."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        return jnp.uint64((0xFFFFFFFFFFFFFFFF >> (52 - keep)) << (52 - keep)), jnp.uint64
    if dtype == jnp.float32:
        return jnp.uint32((0xFFFFFFFF >> (23 - keep)) << (23 - keep)), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def _mask_split_keep(a, keep: int):
    mask, uint = _mask_keep(a.dtype, keep)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    return hi, a - hi


def two_prod_terms(a, b):
    """a*b as a list of floats summing to the product EXACTLY.

    The low x low partial of the two-way mask split can carry one bit too
    many (f64), so its second factor is re-split; every returned term is an
    exactly-representable product, keeping the decomposition both exact and
    fma-contraction-proof.  Used by the quad-word layer, where two_prod's
    2^-105 slack would dominate the error budget.
    """
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    if jnp.dtype(a.dtype) == jnp.float64:
        blh, bll = _mask_split_keep(bl, 12)  # 27-bit al x {13, 14}-bit halves
        return [ah * bh, ah * bl, al * bh, al * blh, al * bll]
    return [ah * bh, ah * bl, al * bh, al * bl]  # f32: 12/12 split, all exact


def two_prod_exact(a, b):
    """Exact two_prod: p + e == a*b exactly (distilled from exact terms)."""
    terms = two_prod_terms(a, b)
    for _ in range(3):  # vecsum sweeps converge the fixed-size expansion
        out = [None] * len(terms)
        s = terms[-1]
        for i in range(len(terms) - 2, -1, -1):
            s, err = two_sum(terms[i], s)
            out[i + 1] = err
        out[0] = s
        terms = out
    # fold the (now far-below-ulp^2) tail exactly into the second limb
    e = terms[1]
    for t in terms[2:]:
        e, r = two_sum(e, t)
        # r is zero after convergence; add it anyway to keep exactness
        e = e + r
    return quick_two_sum(terms[0], e)
