"""Error-free transformations (EFTs) — the primitive "DSP blocks" of this port.

The paper composes binary128 multiply-add units out of FPGA DSP blocks.  On a
TPU the native units are f32 (VPU lanes) and bf16 (MXU); we compose wide
arithmetic out of them with error-free transformations:

  two_sum(a, b)        -> (s, e)  with  s = fl(a+b),  s + e == a + b  exactly
  quick_two_sum(a, b)  -> same, requires |a| >= |b| (3 ops instead of 6)
  two_prod(a, b)       -> (p, e)  with  p + e == a * b * (1 + eps_tp)

Compiler-safety design note (important, discovered empirically):
XLA:CPU's LLVM backend performs FMA *contraction* — a float multiply feeding
an add/subtract inside one fused loop may be emitted as a single fma, so the
add sees the UNROUNDED product.  Classic Dekker two_prod subtracts the
rounded ``p = fl(a*b)`` from partial products; if the compiler rematerializes
``a*b`` into that subtraction as an fma, the error term collapses.  The
implementation below is **contraction-robust by construction**:

  * the operand split uses integer mantissa masking (no float multiply, so
    nothing to contract; Veltkamp's ``C*a`` trick is itself contractible);
  * ``p`` is assembled from the four *exact* partial products with two_sum
    chains — every multiply that reaches an add is exactly representable, so
    fma contraction cannot change any value.

Cost: two_prod is no longer bit-exact; its relative error is <= ~2^-2p+2
(2^-105 for f64 limbs, 2^-47 for f32), from (a) rounding when summing the
three two_sum error terms and (b) the lowest partial product carrying
p+1 bits under the mask split (no Veltkamp sign trick).  Double-word
arithmetic built on it keeps relative error ~2^-104 / ~2^-46 — the same
class as the binary128 target (and as the paper's own DD-based related
work).  Property tests pin these bounds against Fraction oracles.

All algorithms assume round-to-nearest and flush-to-zero-free inputs in the
normal range (XLA:CPU flushes subnormals).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "two_sum",
    "quick_two_sum",
    "mask_split",
    "two_prod",
    "two_prod_terms",
    "two_prod_exact",
    "TWO_PROD_RELERR",
]

# relative error bound of two_prod per limb dtype (see module docstring)
TWO_PROD_RELERR = {
    jnp.dtype(jnp.float64): 2.0**-104,
    jnp.dtype(jnp.float32): 2.0**-46,
}


def two_sum(a, b):
    """Knuth's branch-free exact addition: s + e == a + b exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def quick_two_sum(a, b):
    """Dekker's fast exact addition. Exact when |a| >= |b| (or a == 0)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _mask_for(dtype):
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        # clear low 27 of 52 explicit mantissa bits -> hi has 26 bits
        return jnp.uint64(0xFFFFFFFFF8000000), jnp.uint64
    if dtype == jnp.float32:
        # clear low 12 of 23 explicit mantissa bits -> hi has 12 bits
        return jnp.uint32(0xFFFFF000), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def mask_split(a):
    """Split a == hi + lo exactly by masking low mantissa bits (integer ops).

    hi keeps the top ~p/2 mantissa bits; lo = a - hi is exact because hi and
    a share sign/exponent and agree on high bits (Sterbenz).  Unlike the
    Veltkamp split there is no float multiply for the compiler to contract.
    """
    mask, uint = _mask_for(a.dtype)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    lo = a - hi
    return hi, lo


# Scale-aware operand rescue (extreme-scale exactness).  The mask split's
# low part has magnitude down to 2^(e-52); for |a| below ~2^-970 it lands
# in the subnormal range, which XLA:CPU flushes to zero — silently losing
# the m2/m4 partial products (measured: up to ~2^-25 relative error on
# dd.mul for operand pairs like 2^1005 x 2^-1005 whose PRODUCT is
# perfectly representable).  The rescue pre-scales each operand by an
# exact power of two into a safe band and unscales the result; in the
# normal band the factor is exactly 1.0, so in-range results are
# bit-identical to the unscaled computation.
#
# Band arithmetic (f64; f32 analogous with p=23, emax=127):
#   * operands with |x| < 2^-484 scale UP by 2^512, |x| > 2^484 scale DOWN
#     by 2^-512;
#   * every reachable scaled-exponent pair sum lies in [-968, 1023], so no
#     partial product overflows and none is flushed beyond its ordinary
#     <= 1/2 ulp rounding allowance (pairs summing below -968 have
#     products whose dd tail is sub-representable anyway — inherent);
#   * unscaling applies the > 1 inverse factors BEFORE the < 1 ones, so a
#     huge x tiny product never transits the subnormal range on its way
#     back (and the combined factor 2^{+-1024}, which is not itself
#     representable, is never formed).
_RESCUE = {
    jnp.dtype(jnp.float64): (2.0 ** -484, 2.0 ** 484, 2.0 ** 512,
                             2.0 ** -512),
    jnp.dtype(jnp.float32): (2.0 ** -60, 2.0 ** 60, 2.0 ** 64, 2.0 ** -64),
}


def _rescue(x):
    """(x * s, 1/s) with s an exact pow2 moving x into the safe band.

    s == 1 exactly for in-band operands; NaN/Inf/0 pass through (the
    comparisons are False on NaN, Inf scales down but stays Inf, 0 scales
    up and stays 0).
    """
    tiny, huge, up, down = _RESCUE[jnp.dtype(x.dtype)]
    ax = jnp.abs(x)
    s = jnp.where(ax < tiny, up, jnp.where(ax > huge, down, 1.0))
    inv = jnp.where(ax < tiny, down, jnp.where(ax > huge, up, 1.0))
    return x * s, inv


def _unscale(x, inv_a, inv_b):
    """x * inv_a * inv_b, > 1 factors first (no intermediate under/overflow)."""
    one = jnp.ones((), x.dtype)
    x = x * jnp.maximum(inv_a, one)
    x = x * jnp.maximum(inv_b, one)
    x = x * jnp.minimum(inv_a, one)
    return x * jnp.minimum(inv_b, one)


def two_prod(a, b):
    """Near-exact multiplication: p + e == a*b up to TWO_PROD_RELERR[dtype].

    The four partial products of the mask splits are (near-)exactly
    representable, so assembling them with two_sum chains is immune to fma
    contraction (see module docstring).  ``p`` is within 1 ulp of fl(a*b).
    Operands are pow2-rescued into the safe exponent band first, so the
    bound holds out to the edges of the representable range (see _RESCUE);
    in-band operands compute bit-identically to the unscaled algorithm.
    """
    a, inv_a = _rescue(a)
    b, inv_b = _rescue(b)
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    m1 = ah * bh  # exact
    m2 = ah * bl  # exact
    m3 = al * bh  # exact
    m4 = al * bl  # <= 1/2 ulp error at 2^-(2p+2)|ab| scale (p+1-bit operands)
    s, e1 = two_sum(m1, m2)
    s, e2 = two_sum(s, m3)
    s, e3 = two_sum(s, m4)
    e = e1 + (e2 + e3)
    return _unscale(s, inv_a, inv_b), _unscale(e, inv_a, inv_b)


def _mask_keep(dtype, keep: int):
    """Mask clearing all but the top ``keep`` explicit mantissa bits."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        return jnp.uint64((0xFFFFFFFFFFFFFFFF >> (52 - keep)) << (52 - keep)), jnp.uint64
    if dtype == jnp.float32:
        return jnp.uint32((0xFFFFFFFF >> (23 - keep)) << (23 - keep)), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def _mask_split_keep(a, keep: int):
    mask, uint = _mask_keep(a.dtype, keep)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    return hi, a - hi


def two_prod_terms(a, b):
    """a*b as a list of floats summing to the product EXACTLY.

    The low x low partial of the two-way mask split can carry one bit too
    many (f64), so its second factor is re-split; every returned term is an
    exactly-representable product, keeping the decomposition both exact and
    fma-contraction-proof.  Used by the quad-word layer, where two_prod's
    2^-105 slack would dominate the error budget.  Operands get the same
    pow2 rescue as two_prod (each term is unscaled individually — exact,
    since the factors are powers of two), so the decomposition stays exact
    out to the edges of the representable range.
    """
    terms, inv_a, inv_b = _scaled_terms(a, b)
    return [_unscale(t, inv_a, inv_b) for t in terms]


def _scaled_terms(a, b):
    """Exact product terms of the rescued operands, plus the inverses."""
    a, inv_a = _rescue(a)
    b, inv_b = _rescue(b)
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    if jnp.dtype(a.dtype) == jnp.float64:
        blh, bll = _mask_split_keep(bl, 12)  # 27-bit al x {13, 14}-bit halves
        terms = [ah * bh, ah * bl, al * bh, al * blh, al * bll]
    else:
        terms = [ah * bh, ah * bl, al * bh, al * bl]  # f32: 12/12, all exact
    return terms, inv_a, inv_b


def two_prod_exact(a, b):
    """Exact two_prod: p + e == a*b exactly (distilled from exact terms).

    Distills in the rescued exponent band and unscales only the final
    (p, e) pair: unscaling the raw terms individually could flush a small
    term that the distilled error limb would have absorbed losslessly.
    """
    terms, inv_a, inv_b = _scaled_terms(a, b)
    for _ in range(3):  # vecsum sweeps converge the fixed-size expansion
        out = [None] * len(terms)
        s = terms[-1]
        for i in range(len(terms) - 2, -1, -1):
            s, err = two_sum(terms[i], s)
            out[i + 1] = err
        out[0] = s
        terms = out
    # fold the (now far-below-ulp^2) tail exactly into the second limb
    e = terms[1]
    for t in terms[2:]:
        e, r = two_sum(e, t)
        # r is zero after convergence; add it anyway to keep exactness
        e = e + r
    p, e = quick_two_sum(terms[0], e)
    return _unscale(p, inv_a, inv_b), _unscale(e, inv_a, inv_b)
