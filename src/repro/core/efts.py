"""Error-free transformations (EFTs) — the primitive "DSP blocks" of this port.

The paper composes binary128 multiply-add units out of FPGA DSP blocks.  On a
TPU the native units are f32 (VPU lanes) and bf16 (MXU); we compose wide
arithmetic out of them with error-free transformations:

  two_sum(a, b)        -> (s, e)  with  s = fl(a+b),  s + e == a + b  exactly
  quick_two_sum(a, b)  -> same, requires |a| >= |b| (3 ops instead of 6)
  two_prod(a, b)       -> (p, e)  with  p + e == a * b * (1 + eps_tp)

Compiler-safety design note (important, discovered empirically):
XLA:CPU's LLVM backend performs FMA *contraction* — a float multiply feeding
an add/subtract inside one fused loop may be emitted as a single fma, so the
add sees the UNROUNDED product.  Classic Dekker two_prod subtracts the
rounded ``p = fl(a*b)`` from partial products; if the compiler rematerializes
``a*b`` into that subtraction as an fma, the error term collapses.  The
implementation below is **contraction-robust by construction**:

  * the operand split uses integer mantissa masking (no float multiply, so
    nothing to contract; Veltkamp's ``C*a`` trick is itself contractible);
  * ``p`` is assembled from the four *exact* partial products with two_sum
    chains — every multiply that reaches an add is exactly representable, so
    fma contraction cannot change any value.

Cost: two_prod is no longer bit-exact; its relative error is <= ~2^-2p+2
(2^-105 for f64 limbs, 2^-47 for f32), from (a) rounding when summing the
three two_sum error terms and (b) the lowest partial product carrying
p+1 bits under the mask split (no Veltkamp sign trick).  Double-word
arithmetic built on it keeps relative error ~2^-104 / ~2^-46 — the same
class as the binary128 target (and as the paper's own DD-based related
work).  Property tests pin these bounds against Fraction oracles.

All algorithms assume round-to-nearest and flush-to-zero-free inputs in the
normal range (XLA:CPU flushes subnormals).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "two_sum",
    "quick_two_sum",
    "mask_split",
    "two_prod",
    "two_prod_terms",
    "two_prod_exact",
    "TWO_PROD_RELERR",
]

# relative error bound of two_prod per limb dtype (see module docstring)
TWO_PROD_RELERR = {
    jnp.dtype(jnp.float64): 2.0**-104,
    jnp.dtype(jnp.float32): 2.0**-46,
}


def two_sum(a, b):
    """Knuth's branch-free exact addition: s + e == a + b exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def quick_two_sum(a, b):
    """Dekker's fast exact addition. Exact when |a| >= |b| (or a == 0)."""
    s = a + b
    e = b - (s - a)
    return s, e


def _mask_for(dtype):
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        # clear low 27 of 52 explicit mantissa bits -> hi has 26 bits
        return jnp.uint64(0xFFFFFFFFF8000000), jnp.uint64
    if dtype == jnp.float32:
        # clear low 12 of 23 explicit mantissa bits -> hi has 12 bits
        return jnp.uint32(0xFFFFF000), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def mask_split(a):
    """Split a == hi + lo exactly by masking low mantissa bits (integer ops).

    hi keeps the top ~p/2 mantissa bits; lo = a - hi is exact because hi and
    a share sign/exponent and agree on high bits (Sterbenz).  Unlike the
    Veltkamp split there is no float multiply for the compiler to contract.
    """
    mask, uint = _mask_for(a.dtype)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    lo = a - hi
    return hi, lo


# Scale-aware operand rescue (extreme-scale exactness).  The mask split's
# low part has magnitude down to 2^(e-52); for |a| below ~2^-970 it lands
# in the subnormal range, which XLA:CPU flushes to zero — silently losing
# the m2/m4 partial products (measured: up to ~2^-25 relative error on
# dd.mul for operand pairs like 2^1005 x 2^-1005 whose PRODUCT is
# perfectly representable).  The rescue pre-scales each operand by an
# exact power of two into a safe band and unscales the result; in the
# normal band the factor is exactly 1.0, so in-range results are
# bit-identical to the unscaled computation.
#
# Band arithmetic (f64; f32 analogous with p=23, emax=127):
#   * operands with |x| < 2^-484 scale UP by 2^512, |x| > 2^484 scale DOWN
#     by 2^-512;
#   * every reachable scaled-exponent pair sum lies in [-968, 1023], so no
#     partial product overflows and none is flushed beyond its ordinary
#     <= 1/2 ulp rounding allowance (pairs summing below -968 have
#     products whose dd tail is sub-representable anyway — inherent);
#   * the rescue scales ride along as INTEGER exponents, so unscaling sums
#     them first — an up-rescue and a down-rescue cancel to 0 before any
#     float factor exists.  (Applying the inverse *factors* in any fixed
#     order is wrong: for a huge x tiny pair whose product is large but
#     representable, e.g. 2^1020 x 2^-485, the >1-first order sends the
#     2^535-scale intermediate through 2^1047 = Inf.)  A same-direction
#     residual of +-2*shift is applied as two normal-range half factors,
#     since 2^{+-1024} is not itself representable.
_RESCUE = {
    jnp.dtype(jnp.float64): (2.0 ** -484, 2.0 ** 484, 2.0 ** 512, 512),
    jnp.dtype(jnp.float32): (2.0 ** -60, 2.0 ** 60, 2.0 ** 64, 64),
}


def _rescue(x):
    """(x * s, e) with s = 2^-e an exact pow2 moving x into the safe band.

    The returned e is the integer UNSCALE exponent (x*s * 2^e == x * 2^0
    scale-wise); e == 0 for in-band operands, where s == 1 exactly.
    NaN/Inf/0 pass through (the comparisons are False on NaN, Inf scales
    down but stays Inf, 0 scales up and stays 0).
    """
    tiny, huge, up, shift = _RESCUE[jnp.dtype(x.dtype)]
    ax = jnp.abs(x)
    s = jnp.where(ax < tiny, up, jnp.where(ax > huge, 1.0 / up, 1.0))
    e = jnp.where(ax < tiny, jnp.int32(-shift),
                  jnp.where(ax > huge, jnp.int32(shift), jnp.int32(0)))
    return x * s, e


def _pow2(e, dtype):
    """Exact 2.0**e via exponent-field bitcast (e in the normal range)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        bits = ((e.astype(jnp.int64) + 1023) << 52).astype(jnp.uint64)
        return jax.lax.bitcast_convert_type(bits, jnp.float64)
    if dtype == jnp.float32:
        bits = ((e.astype(jnp.int32) + 127) << 23).astype(jnp.uint32)
        return jax.lax.bitcast_convert_type(bits, jnp.float32)
    raise ValueError(f"unsupported limb dtype {dtype}")


def _unscale(x, ea, eb):
    """x * 2^(ea+eb) with opposite-direction rescues cancelling exactly.

    The integer sum ea+eb is formed before any float factor, so a mixed
    huge x tiny pair unscales by 2^0 == 1 and never transits Inf or the
    flushed subnormal range.  A same-direction sum (|ea+eb| = 2*shift,
    whose single factor would be unrepresentable) is applied as two exact
    normal-range halves; each half is a pow2, so every multiply is exact
    wherever the true result is representable.
    """
    e = ea + eb
    h = e // 2  # shift sums are even, so h == e - h == e/2
    return x * _pow2(h, x.dtype) * _pow2(e - h, x.dtype)


def two_prod(a, b):
    """Near-exact multiplication: p + e == a*b up to TWO_PROD_RELERR[dtype].

    The four partial products of the mask splits are (near-)exactly
    representable, so assembling them with two_sum chains is immune to fma
    contraction (see module docstring).  ``p`` is within 1 ulp of fl(a*b).
    Operands are pow2-rescued into the safe exponent band first, so the
    bound holds out to the edges of the representable range (see _RESCUE);
    in-band operands compute bit-identically to the unscaled algorithm.
    """
    a, ea = _rescue(a)
    b, eb = _rescue(b)
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    m1 = ah * bh  # exact
    m2 = ah * bl  # exact
    m3 = al * bh  # exact
    m4 = al * bl  # <= 1/2 ulp error at 2^-(2p+2)|ab| scale (p+1-bit operands)
    s, e1 = two_sum(m1, m2)
    s, e2 = two_sum(s, m3)
    s, e3 = two_sum(s, m4)
    e = e1 + (e2 + e3)
    return _unscale(s, ea, eb), _unscale(e, ea, eb)


def _mask_keep(dtype, keep: int):
    """Mask clearing all but the top ``keep`` explicit mantissa bits."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        return jnp.uint64((0xFFFFFFFFFFFFFFFF >> (52 - keep)) << (52 - keep)), jnp.uint64
    if dtype == jnp.float32:
        return jnp.uint32((0xFFFFFFFF >> (23 - keep)) << (23 - keep)), jnp.uint32
    raise ValueError(f"unsupported limb dtype {dtype}")


def _mask_split_keep(a, keep: int):
    mask, uint = _mask_keep(a.dtype, keep)
    bits = jax.lax.bitcast_convert_type(a, uint)
    hi = jax.lax.bitcast_convert_type(bits & mask, a.dtype)
    return hi, a - hi


def two_prod_terms(a, b):
    """a*b as a list of floats summing to the product EXACTLY.

    The low x low partial of the two-way mask split can carry one bit too
    many (f64), so its second factor is re-split; every returned term is an
    exactly-representable product, keeping the decomposition both exact and
    fma-contraction-proof.  Used by the quad-word layer, where two_prod's
    2^-105 slack would dominate the error budget.  Operands get the same
    pow2 rescue as two_prod (each term is unscaled individually — exact,
    since the factors are powers of two), so the decomposition stays exact
    out to the edges of the representable range.
    """
    terms, ea, eb = _scaled_terms(a, b)
    return [_unscale(t, ea, eb) for t in terms]


def _scaled_terms(a, b):
    """Exact product terms of the rescued operands, plus unscale exponents."""
    a, ea = _rescue(a)
    b, eb = _rescue(b)
    ah, al = mask_split(a)
    bh, bl = mask_split(b)
    if jnp.dtype(a.dtype) == jnp.float64:
        blh, bll = _mask_split_keep(bl, 12)  # 27-bit al x {13, 14}-bit halves
        terms = [ah * bh, ah * bl, al * bh, al * blh, al * bll]
    else:
        terms = [ah * bh, ah * bl, al * bh, al * bl]  # f32: 12/12, all exact
    return terms, ea, eb


def two_prod_exact(a, b):
    """Exact two_prod: p + e == a*b exactly (distilled from exact terms).

    Distills in the rescued exponent band and unscales only the final
    (p, e) pair: unscaling the raw terms individually could flush a small
    term that the distilled error limb would have absorbed losslessly.
    """
    terms, ea, eb = _scaled_terms(a, b)
    for _ in range(3):  # vecsum sweeps converge the fixed-size expansion
        out = [None] * len(terms)
        s = terms[-1]
        for i in range(len(terms) - 2, -1, -1):
            s, err = two_sum(terms[i], s)
            out[i + 1] = err
        out[0] = s
        terms = out
    # fold the (now far-below-ulp^2) tail exactly into the second limb
    e = terms[1]
    for t in terms[2:]:
        e, r = two_sum(e, t)
        # r is zero after convergence; add it anyway to keep exactness
        e = e + r
    p, e = quick_two_sum(terms[0], e)
    return _unscale(p, ea, eb), _unscale(e, ea, eb)
