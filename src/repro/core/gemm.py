"""Backend dispatch for binary128-class GEMM.

Backends (all produce DD results with ~2^-104-grade accumulation):

  pallas — the systolic-tile Pallas kernel (kernels/ddgemm.py); the paper's
           design.  interpret-mode on CPU, native on TPU.
  ozaki  — error-free slicing onto native GEMMs (core/ozaki.py); the
           beyond-paper MXU path.  Fastest on both CPU (f64 XLA dot) and
           TPU (bf16 MXU dot).
  xla    — blocked jnp DD matmul (kernels/ops.matmul_dd_xla); portable
           fallback.
  ref    — O(m*k*n)-memory oracle (kernels/ref.py); tests only.

``auto`` picks ozaki (it rides the platform's native dot and is the fastest
correct path everywhere); the paper-faithful kernel remains selectable per
call or via REPRO_GEMM_BACKEND.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from . import dd, ozaki

__all__ = ["matmul", "BACKENDS"]

BACKENDS = ("auto", "pallas", "ozaki", "xla", "ref")


def matmul(a: dd.DD, b: dd.DD, *, backend: str = "auto", **kwargs) -> dd.DD:
    """C = A @ B in double-word arithmetic via the selected backend."""
    backend = backend if backend != "auto" else os.environ.get(
        "REPRO_GEMM_BACKEND", "ozaki")
    if backend == "ozaki":
        return ozaki.ozaki_gemm(a, b, **kwargs)
    if backend == "pallas":
        from repro.kernels.ops import ddgemm

        return ddgemm(a, b, **kwargs)
    if backend == "xla":
        from repro.kernels.ops import matmul_dd_xla

        return matmul_dd_xla(a, b, **kwargs)
    if backend == "ref":
        from repro.kernels.ref import ddgemm_ref

        return ddgemm_ref(a, b)
    raise ValueError(f"unknown GEMM backend {backend!r}; one of {BACKENDS}")
