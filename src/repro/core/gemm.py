"""Backend dispatch for extended-precision GEMM — compatibility shim.

The real machinery lives in ``repro.gemm`` (the unified execution engine:
plan -> autotune -> dispatch, see DESIGN.md §4).  This module keeps the
original ``matmul(a, b, backend=...)`` surface for existing call sites and
examples; new code should use ``repro.gemm.matmul`` / ``make_plan`` /
``execute`` directly, which also expose batched and multi-device sharded
execution and the precision ladder (DESIGN.md §8 — the engine infers
``"dd"`` vs ``"qd"`` from the operand type).

Backends (dd tier ~2^-104-grade accumulation; qd tier ~2^-205):

  pallas — the systolic-tile Pallas kernels (kernels/ddgemm.py,
           kernels/qdgemm.py); the paper's design.  interpret-mode on CPU,
           native on TPU.
  ozaki  — whole-K error-free slicing onto native GEMMs with
           diagonal-grouped recombination (core/ozaki.py); the fastest
           CPU path (f64 XLA dot).  dd tier only.
  ozaki-pallas — the fused per-K-slab slicing kernel (kernels/ozgemm.py):
           slice-pair dots on the MXU, recombination in VMEM scratch,
           fused alpha/beta drain.  dd and qd tiers; the TPU target.
  xla    — blocked jnp multi-limb matmul (kernels/ops.matmul_dd_xla /
           matmul_qd_xla); portable fallback.
  ref    — O(m*k*n)-memory oracles (kernels/ref.py); tests only.

``auto`` picks ozaki for dd (it rides the platform's native dot and is the
fastest correct path everywhere) and xla for qd; the paper-faithful kernel
remains selectable per call or via REPRO_GEMM_BACKEND.
"""

from __future__ import annotations

from repro.gemm import BACKENDS, matmul as _engine_matmul

from . import dd

__all__ = ["matmul", "BACKENDS"]


def matmul(a: dd.DD, b: dd.DD, *, backend: str = "auto", **kwargs) -> dd.DD:
    """C = A @ B in double-word arithmetic via the selected backend."""
    return _engine_matmul(a, b, backend=backend, **kwargs)
