"""Binary128-class dense linear algebra on top of the DD GEMM (paper §V-A).

``rgetrf`` is the blocked right-looking LU of MPLAPACK's Rgetrf exactly as
the paper modifies it: panel factorization + triangular solve on the host
path, and the O(n^3) trailing update ``A22 -= L21 @ U12`` routed through the
accelerated ``rgemm`` (step 5 of the paper's algorithm, the part it offloads
to the FPGA).  ``rpotrf``/``rtrsm`` supply the Cholesky machinery the SDP
solver (core/sdp.py) needs.

Panel/solve kernels are jitted with masked fori_loops (static shapes, traced
indices); the outer block loop runs on the host like the paper's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import dd
from .blas import rgemm

__all__ = [
    "rgetrf",
    "rgetrf2",
    "rtrsm",
    "rpotrf",
    "lu_solve",
    "cholesky_solve",
    "apply_pivots",
]


def _dyn_cell(x: dd.DD, i, j) -> dd.DD:
    hi = jax.lax.dynamic_slice(x.hi, (i, j), (1, 1))
    lo = jax.lax.dynamic_slice(x.lo, (i, j), (1, 1))
    return dd.DD(hi, lo)


@functools.partial(jax.jit, static_argnames=())
def rgetrf2(a_hi, a_lo):
    """Unblocked LU with partial pivoting on an (m, nb) panel. Jitted.

    Returns (lu_hi, lu_lo, piv) with piv[j] = row swapped with j at step j.
    """
    m, nb = a_hi.shape
    rows = jnp.arange(m)
    cols = jnp.arange(nb)

    def step(j, carry):
        hi, lo, piv = carry
        col_hi = jax.lax.dynamic_slice(hi, (0, j), (m, 1))[:, 0]
        cand = jnp.where(rows >= j, jnp.abs(col_hi), -1.0)
        p = jnp.argmax(cand)
        # swap rows j <-> p
        idx = jnp.where(rows == j, p, jnp.where(rows == p, j, rows))
        hi, lo = hi[idx], lo[idx]
        piv = jnp.where(cols == j, p.astype(piv.dtype), piv)
        pivot = _dyn_cell(dd.DD(hi, lo), j, j)  # (1,1)
        col = dd.DD(
            jax.lax.dynamic_slice(hi, (0, j), (m, 1)),
            jax.lax.dynamic_slice(lo, (0, j), (m, 1)),
        )
        below = (rows > j)[:, None]
        scaled = dd.div(col, dd.DD(jnp.broadcast_to(pivot.hi, col.shape),
                                   jnp.broadcast_to(pivot.lo, col.shape)))
        new_col = dd.where(below, scaled, col)
        col_sel = (cols == j)[None, :]
        hi = jnp.where(col_sel, new_col.hi, hi)
        lo = jnp.where(col_sel, new_col.lo, lo)
        # trailing rank-1 update: A[i, c] -= L[i, j] * U[j, c]  (i > j, c > j)
        urow = dd.DD(
            jax.lax.dynamic_slice(hi, (j, 0), (1, nb)),
            jax.lax.dynamic_slice(lo, (j, 0), (1, nb)),
        )
        upd = dd.mul(new_col, urow)  # (m, nb) broadcast outer product
        mask = below & (cols > j)[None, :]
        cur = dd.DD(hi, lo)
        newm = dd.sub(cur, upd)
        hi = jnp.where(mask, newm.hi, hi)
        lo = jnp.where(mask, newm.lo, lo)
        return hi, lo, piv

    piv0 = jnp.zeros(nb, dtype=jnp.int32)
    hi, lo, piv = jax.lax.fori_loop(0, min(m, nb), step, (a_hi, a_lo, piv0))
    return hi, lo, piv


@functools.partial(jax.jit, static_argnames=("lower", "unit_diag", "transpose_a"))
def _trsm(l_hi, l_lo, b_hi, b_lo, *, lower: bool, unit_diag: bool,
          transpose_a: bool):
    """Solve op(T) X = B for triangular T, forward/backward substitution."""
    if transpose_a:
        l_hi, l_lo, lower = l_hi.T, l_lo.T, not lower
    nb = l_hi.shape[0]
    n = b_hi.shape[1]
    t = dd.DD(l_hi, l_lo)
    rows = jnp.arange(nb)

    def solve_row(i, carry):
        x_hi, x_lo = carry
        # i-th row of T, masked to the already-solved triangle
        trow = dd.DD(
            jax.lax.dynamic_slice(l_hi, (i, 0), (1, nb))[0],
            jax.lax.dynamic_slice(l_lo, (i, 0), (1, nb))[0],
        )
        solved_mask = (rows < i) if lower else (rows > i)
        tcol = dd.where(solved_mask[:, None], dd.DD(trow.hi[:, None], trow.lo[:, None]),
                        dd.zeros((nb, 1)))
        contrib = dd.sum_(dd.mul(tcol, dd.DD(x_hi, x_lo)), axis=0)  # (n,)
        brow = dd.DD(
            jax.lax.dynamic_slice(b_hi, (i, 0), (1, n))[0],
            jax.lax.dynamic_slice(b_lo, (i, 0), (1, n))[0],
        )
        xi = dd.sub(brow, contrib)
        if not unit_diag:
            piv = _dyn_cell(t, i, i)
            xi = dd.div(xi, dd.DD(jnp.broadcast_to(piv.hi[0], xi.shape),
                                  jnp.broadcast_to(piv.lo[0], xi.shape)))
        sel = (rows == i)[:, None]
        x_hi = jnp.where(sel, xi.hi[None, :], x_hi)
        x_lo = jnp.where(sel, xi.lo[None, :], x_lo)
        return x_hi, x_lo

    x0 = (jnp.zeros_like(b_hi), jnp.zeros_like(b_lo))
    if lower:
        x_hi, x_lo = jax.lax.fori_loop(0, nb, solve_row, x0)
    else:
        x_hi, x_lo = jax.lax.fori_loop(
            0, nb, lambda k, c: solve_row(nb - 1 - k, c), x0)
    return x_hi, x_lo


def rtrsm(t: dd.DD, b: dd.DD, *, lower: bool = True, unit_diag: bool = False,
          transpose_a: bool = False) -> dd.DD:
    hi, lo = _trsm(t.hi, t.lo, b.hi, b.lo, lower=lower, unit_diag=unit_diag,
                   transpose_a=transpose_a)
    return dd.DD(hi, lo)


def apply_pivots(x: dd.DD, piv: np.ndarray, offset: int = 0) -> dd.DD:
    """Apply LAPACK-style sequential row interchanges piv (local indices)."""
    perm = np.arange(x.shape[0])
    for j, p in enumerate(np.asarray(piv)):
        pj = int(p) + offset
        jj = j + offset
        perm[jj], perm[pj] = perm[pj], perm[jj]
    idx = jnp.asarray(perm)
    return dd.DD(x.hi[idx], x.lo[idx])


def rgetrf(a: dd.DD, block: int = 64, plan=None, **plan_overrides):
    """Blocked LU with partial pivoting (paper's Rgetrf, steps 1-6).

    Returns (lu, piv) with L\\U packed and piv the global LAPACK-style
    interchange vector.  The trailing updates go through the engine-planned
    ``rgemm``: each shrinking (m-p, nb, n-p) update shape is planned per
    call, so tuned block entries from the autotune cache (bucketed by shape)
    are reused across the sweep instead of hardcoded DEFAULT_BLOCKS.
    """
    m, n = a.shape
    assert m == n, "square only (paper's setting)"
    lu = a
    piv_global = np.zeros(n, dtype=np.int64)
    for p0 in range(0, n, block):
        nb = min(block, n - p0)
        panel = dd.DD(lu.hi[p0:, p0:p0 + nb], lu.lo[p0:, p0:p0 + nb])
        ph, plo, ppiv = rgetrf2(panel.hi, panel.lo)
        ppiv = np.asarray(ppiv)
        piv_global[p0:p0 + nb] = ppiv + p0
        # apply the panel's row swaps to the columns outside the panel
        rest = dd.DD(lu.hi[p0:, :], lu.lo[p0:, :])
        rest = apply_pivots(rest, ppiv)
        hi = rest.hi.at[:, p0:p0 + nb].set(ph)
        lo = rest.lo.at[:, p0:p0 + nb].set(plo)
        lu = dd.DD(
            jnp.concatenate([lu.hi[:p0], hi], axis=0),
            jnp.concatenate([lu.lo[:p0], lo], axis=0),
        )
        if p0 + nb < n:
            l11 = dd.DD(lu.hi[p0:p0 + nb, p0:p0 + nb],
                        lu.lo[p0:p0 + nb, p0:p0 + nb])
            a12 = dd.DD(lu.hi[p0:p0 + nb, p0 + nb:],
                        lu.lo[p0:p0 + nb, p0 + nb:])
            u12 = rtrsm(l11, a12, lower=True, unit_diag=True)
            hi = lu.hi.at[p0:p0 + nb, p0 + nb:].set(u12.hi)
            lo = lu.lo.at[p0:p0 + nb, p0 + nb:].set(u12.lo)
            lu = dd.DD(hi, lo)
            # the accelerated step: A22 -= L21 @ U12
            l21 = dd.DD(lu.hi[p0 + nb:, p0:p0 + nb],
                        lu.lo[p0 + nb:, p0:p0 + nb])
            a22 = dd.DD(lu.hi[p0 + nb:, p0 + nb:],
                        lu.lo[p0 + nb:, p0 + nb:])
            upd = rgemm("n", "n", -1.0, l21, u12, 1.0, a22, plan=plan,
                        **plan_overrides)
            hi = lu.hi.at[p0 + nb:, p0 + nb:].set(upd.hi)
            lo = lu.lo.at[p0 + nb:, p0 + nb:].set(upd.lo)
            lu = dd.DD(hi, lo)
    return lu, piv_global


def lu_solve(lu: dd.DD, piv: np.ndarray, b: dd.DD) -> dd.DD:
    """Solve A x = b given rgetrf output (forward + backward substitution)."""
    n = lu.shape[0]
    perm = np.arange(n)
    for j, p in enumerate(np.asarray(piv)):
        perm[j], perm[p] = perm[p], perm[j]
    idx = jnp.asarray(perm)
    pb = dd.DD(b.hi[idx], b.lo[idx])
    y = rtrsm(lu, pb, lower=True, unit_diag=True)
    return rtrsm(lu, y, lower=False, unit_diag=False)


@functools.partial(jax.jit, static_argnames=())
def _potrf(a_hi, a_lo):
    n = a_hi.shape[0]
    rows = jnp.arange(n)

    def step(j, carry):
        l_hi, l_lo = carry
        lmat = dd.DD(l_hi, l_lo)
        # d = sqrt(a_jj - sum_{k<j} L[j,k]^2)
        rowj = dd.DD(
            jax.lax.dynamic_slice(l_hi, (j, 0), (1, n))[0],
            jax.lax.dynamic_slice(l_lo, (j, 0), (1, n))[0],
        )
        maskk = (rows < j)
        rowj = dd.where(maskk, rowj, dd.zeros((n,)))
        s = dd.sum_(dd.mul(rowj, rowj), axis=0)
        ajj = _dyn_cell(lmat, j, j)
        d = dd.sqrt(dd.sub(dd.DD(ajj.hi[0, 0], ajj.lo[0, 0]), s))
        # column below: L[i,j] = (A[i,j] - sum_k L[i,k] L[j,k]) / d
        colA = dd.DD(
            jax.lax.dynamic_slice(l_hi, (0, j), (n, 1))[:, 0],
            jax.lax.dynamic_slice(l_lo, (0, j), (n, 1))[:, 0],
        )
        lik = dd.where(maskk[None, :], lmat, dd.zeros((n, n)))  # (n, k<j)
        contrib = dd.sum_(dd.mul(lik, dd.DD(rowj.hi[None, :], rowj.lo[None, :])), axis=1)
        num = dd.sub(colA, contrib)
        col = dd.div(num, dd.DD(jnp.broadcast_to(d.hi, num.shape),
                                jnp.broadcast_to(d.lo, num.shape)))
        below = rows > j
        diag = rows == j
        new_hi = jnp.where(below, col.hi, jnp.where(diag, d.hi, 0.0))
        new_lo = jnp.where(below, col.lo, jnp.where(diag, d.lo, 0.0))
        sel = (rows == j)[None, :]
        l_hi = jnp.where(sel, new_hi[:, None], l_hi)
        l_lo = jnp.where(sel, new_lo[:, None], l_lo)
        return l_hi, l_lo

    l_hi, l_lo = jax.lax.fori_loop(0, n, step, (a_hi, a_lo))
    return jnp.tril(l_hi), jnp.tril(l_lo)


def rpotrf(a: dd.DD) -> dd.DD:
    """Lower Cholesky factor in DD arithmetic: A = L L^T."""
    hi, lo = _potrf(a.hi, a.lo)
    return dd.DD(hi, lo)


def cholesky_solve(l: dd.DD, b: dd.DD) -> dd.DD:
    """Solve (L L^T) x = b."""
    y = rtrsm(l, b, lower=True, unit_diag=False)
    return rtrsm(l, y, lower=True, unit_diag=False, transpose_a=True)
