"""Extended-precision dense linear algebra on top of the GEMM engine (§V-A).

``rgetrf`` is the blocked right-looking LU of MPLAPACK's Rgetrf exactly as
the paper modifies it: panel factorization + triangular solve on the host
path, and the O(n^3) trailing update ``A22 -= L21 @ U12`` routed through the
accelerated ``rgemm`` (step 5 of the paper's algorithm, the part it offloads
to the FPGA).  ``rpotrf``/``rtrsm`` supply the Cholesky machinery the SDP
solver (core/sdp.py) needs.

Every routine is **limb-count generic**: matrices are multi-limb values
(``dd.DD`` with 2 limbs or ``qd.QD`` with 4) and all arithmetic goes through
``core.mp``, so the same blocked algorithms serve the binary128-class tier
and the binary128+ (quad-limb) tier the SDP solver's hardest instances need.
Structural work (slicing, masking, row swaps) is applied limb-wise — limbs
are plain jnp arrays, so shape surgery is precision-agnostic.

Panel/solve kernels are jitted with masked fori_loops (static shapes, traced
indices); limb tuples are pytree arguments, so each limb count compiles its
own specialization.  The outer block loop runs on the host like the paper's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import mp
from .blas import rgemm

__all__ = [
    "rgetrf",
    "rgetrf2",
    "rtrsm",
    "rpotrf",
    "lu_solve",
    "cholesky_solve",
    "apply_pivots",
    "pivot_permutation",
]


def _dyn(x, start, sizes):
    """dynamic_slice applied limb-wise."""
    return mp.map_limbs(lambda l: jax.lax.dynamic_slice(l, start, sizes), x)


@jax.jit
def _rgetrf2(a_limbs):
    """Unblocked LU with partial pivoting on an (m, nb) panel. Jitted.

    ``a_limbs`` is the panel's limb tuple (any supported count); returns
    (limbs, piv) with piv[j] = row swapped with j at step j.
    """
    m, nb = a_limbs[0].shape
    rows = jnp.arange(m)
    cols = jnp.arange(nb)

    def step(j, carry):
        limbs, piv = carry
        col_hi = jax.lax.dynamic_slice(limbs[0], (0, j), (m, 1))[:, 0]
        cand = jnp.where(rows >= j, jnp.abs(col_hi), -1.0)
        p = jnp.argmax(cand)
        # swap rows j <-> p (limb-wise gather)
        idx = jnp.where(rows == j, p, jnp.where(rows == p, j, rows))
        limbs = tuple(l[idx] for l in limbs)
        x = mp.from_limbs(limbs)
        piv = jnp.where(cols == j, p.astype(piv.dtype), piv)
        pivot = _dyn(x, (j, j), (1, 1))  # (1,1)
        col = _dyn(x, (0, j), (m, 1))
        below = (rows > j)[:, None]
        scaled = mp.div(col, mp.broadcast_to(pivot, col.shape))
        new_col = mp.where(below, scaled, col)
        col_sel = (cols == j)[None, :]
        limbs = tuple(
            jnp.where(col_sel, nc, l)
            for nc, l in zip(mp.limbs(new_col), limbs))
        x = mp.from_limbs(limbs)
        # trailing rank-1 update: A[i, c] -= L[i, j] * U[j, c]  (i > j, c > j)
        urow = _dyn(x, (j, 0), (1, nb))
        upd = mp.mul(new_col, urow)  # (m, nb) broadcast outer product
        mask = below & (cols > j)[None, :]
        newm = mp.sub(x, upd)
        limbs = tuple(
            jnp.where(mask, nm, l) for nm, l in zip(mp.limbs(newm), limbs))
        return limbs, piv

    piv0 = jnp.zeros(nb, dtype=jnp.int32)
    limbs, piv = jax.lax.fori_loop(
        0, min(m, nb), step, (tuple(a_limbs), piv0))
    return limbs, piv


def rgetrf2(a_hi, a_lo=None, *more_limbs):
    """Unblocked panel LU.  Accepts either a multi-limb value or raw limbs.

    ``rgetrf2(panel)`` returns ``(panel_lu, piv)``; the legacy spelling
    ``rgetrf2(hi, lo)`` keeps returning ``(hi, lo, piv)``.
    """
    if a_lo is None and not more_limbs:
        limbs, piv = _rgetrf2(tuple(mp.limbs(a_hi)))
        return mp.from_limbs(limbs), piv
    limbs, piv = _rgetrf2((a_hi, a_lo) + more_limbs)
    return (*limbs, piv)


@functools.partial(jax.jit, static_argnames=("lower", "unit_diag",
                                             "transpose_a"))
def _trsm(t_limbs, b_limbs, *, lower: bool, unit_diag: bool,
          transpose_a: bool):
    """Solve op(T) X = B for triangular T, forward/backward substitution."""
    if transpose_a:
        t_limbs = tuple(l.T for l in t_limbs)
        lower = not lower
    nb = t_limbs[0].shape[0]
    n = b_limbs[0].shape[1]
    t = mp.from_limbs(t_limbs)
    b = mp.from_limbs(b_limbs)
    prec = mp.precision_of(t)
    dtype = t_limbs[0].dtype
    rows = jnp.arange(nb)

    def solve_row(i, carry):
        x = mp.from_limbs(carry)
        # i-th row of T, masked to the already-solved triangle
        trow = mp.map_limbs(lambda l: l[0], _dyn(t, (i, 0), (1, nb)))  # (nb,)
        solved_mask = (rows < i) if lower else (rows > i)
        tcol = mp.where(solved_mask[:, None],
                        mp.map_limbs(lambda l: l[:, None], trow),
                        mp.zeros((nb, 1), prec, dtype))
        contrib = mp.sum_(mp.mul(tcol, x), axis=0)  # (n,)
        brow = mp.map_limbs(lambda l: l[0], _dyn(b, (i, 0), (1, n)))
        xi = mp.sub(brow, contrib)
        if not unit_diag:
            piv = mp.map_limbs(lambda l: l[0], _dyn(t, (i, i), (1, 1)))
            xi = mp.div(xi, mp.broadcast_to(piv, xi.shape))
        sel = (rows == i)[:, None]
        return tuple(
            jnp.where(sel, nl[None, :], ol)
            for nl, ol in zip(mp.limbs(xi), carry))

    x0 = tuple(jnp.zeros_like(l) for l in b_limbs)
    if lower:
        out = jax.lax.fori_loop(0, nb, solve_row, x0)
    else:
        out = jax.lax.fori_loop(
            0, nb, lambda k, c: solve_row(nb - 1 - k, c), x0)
    return out


def rtrsm(t, b, *, side: str = "left", lower: bool = True,
          unit_diag: bool = False, transpose_a: bool = False):
    """Triangular solve: op(T) X = B (side='left') or X op(T) = B ('right').

    The right-side form rides the left-side kernel through the transpose
    identity  X op(T) = B  <=>  op(T)^T X^T = B^T  (so both sides share
    one jitted substitution loop per limb count).
    """
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    if side == "right":
        bt = mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), b)
        xt = rtrsm(t, bt, lower=lower, unit_diag=unit_diag,
                   transpose_a=not transpose_a)
        return mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), xt)
    out = _trsm(tuple(mp.limbs(t)), tuple(mp.limbs(b)), lower=lower,
                unit_diag=unit_diag, transpose_a=transpose_a)
    return mp.from_limbs(out)


def pivot_permutation(piv, m: int, offset: int = 0, *,
                      inverse: bool = False):
    """Row permutation equivalent to LAPACK's sequential interchanges.

    ``piv`` is a (traced or concrete) JAX/NumPy int vector with piv[j] =
    the row swapped with ``j + offset`` at step j.  Returns the gather
    index ``perm`` such that ``x[perm]`` applies all nb interchanges in
    order (``inverse=True`` plays them backwards, undoing the forward
    application).  Pure ``lax`` control flow — jit/vmap traceable, so
    pivoted solves can live inside one compiled refinement step.
    """
    piv = jnp.asarray(piv)
    nb = piv.shape[0]

    def swap(j, perm):
        jj = j + offset
        pj = piv[j].astype(perm.dtype) + offset
        vj, vp = perm[jj], perm[pj]
        return perm.at[jj].set(vp).at[pj].set(vj)

    body = (lambda k, p: swap(nb - 1 - k, p)) if inverse else swap
    return jax.lax.fori_loop(0, nb, body, jnp.arange(m, dtype=jnp.int32))


def apply_pivots(x, piv, offset: int = 0, *, inverse: bool = False):
    """Apply LAPACK-style sequential row interchanges piv (local indices).

    Traceable end-to-end: ``piv`` may be a concrete NumPy vector (legacy
    callers) or a traced JAX array (the jitted refinement loop).
    ``inverse=True`` undoes a forward application — the round-trip
    ``apply_pivots(apply_pivots(x, piv), piv, inverse=True) == x`` is
    property-tested.
    """
    perm = pivot_permutation(piv, x.shape[0], offset, inverse=inverse)
    return mp.map_limbs(lambda l: l[perm], x)


def rgetrf(a, block: int = 64, plan=None, **plan_overrides):
    """Blocked LU with partial pivoting (paper's Rgetrf, steps 1-6).

    Returns (lu, piv) with L\\U packed and piv the global LAPACK-style
    interchange vector — a JAX int array end-to-end (no host round-trip),
    so downstream pivoted solves stay jit-traceable.  The trailing updates
    go through the engine-planned ``rgemm``: each shrinking (m-p, nb, n-p)
    update shape is planned per call, so tuned block entries from the
    autotune cache (bucketed by shape and limb count) are reused across
    the sweep instead of DEFAULT_BLOCKS.
    """
    m, n = a.shape
    assert m == n, "square only (paper's setting)"
    lu = a
    piv_parts = []
    for p0 in range(0, n, block):
        nb = min(block, n - p0)
        panel = mp.map_limbs(lambda l: l[p0:, p0:p0 + nb], lu)
        panel_lu, ppiv = rgetrf2(panel)
        piv_parts.append(ppiv + p0)
        # apply the panel's row swaps to the columns outside the panel
        rest = mp.map_limbs(lambda l: l[p0:, :], lu)
        rest = apply_pivots(rest, ppiv)
        rest = mp.from_limbs([
            rl.at[:, p0:p0 + nb].set(pl)
            for rl, pl in zip(mp.limbs(rest), mp.limbs(panel_lu))
        ])
        lu = mp.from_limbs([
            jnp.concatenate([top[:p0], bot], axis=0)
            for top, bot in zip(mp.limbs(lu), mp.limbs(rest))
        ])
        if p0 + nb < n:
            l11 = mp.map_limbs(lambda l: l[p0:p0 + nb, p0:p0 + nb], lu)
            a12 = mp.map_limbs(lambda l: l[p0:p0 + nb, p0 + nb:], lu)
            u12 = rtrsm(l11, a12, lower=True, unit_diag=True)
            lu = mp.from_limbs([
                ll.at[p0:p0 + nb, p0 + nb:].set(ul)
                for ll, ul in zip(mp.limbs(lu), mp.limbs(u12))
            ])
            # the accelerated step: A22 -= L21 @ U12
            l21 = mp.map_limbs(lambda l: l[p0 + nb:, p0:p0 + nb], lu)
            a22 = mp.map_limbs(lambda l: l[p0 + nb:, p0 + nb:], lu)
            upd = rgemm("n", "n", -1.0, l21, u12, 1.0, a22, plan=plan,
                        **plan_overrides)
            lu = mp.from_limbs([
                ll.at[p0 + nb:, p0 + nb:].set(ul)
                for ll, ul in zip(mp.limbs(lu), mp.limbs(upd))
            ])
    return lu, jnp.concatenate(piv_parts)


def lu_solve(lu, piv, b):
    """Solve A x = b given rgetrf output (forward + backward substitution).

    Fully traceable — ``piv`` may be a traced JAX vector, so a refinement
    loop can keep the whole correction solve inside one jit.
    """
    pb = apply_pivots(b, piv)
    y = rtrsm(lu, pb, lower=True, unit_diag=True)
    return rtrsm(lu, y, lower=False, unit_diag=False)


@jax.jit
def _potrf(a_limbs):
    n = a_limbs[0].shape[0]
    a = mp.from_limbs(a_limbs)
    prec = mp.precision_of(a)
    dtype = a_limbs[0].dtype
    rows = jnp.arange(n)

    def step(j, carry):
        lmat = mp.from_limbs(carry)
        # d = sqrt(a_jj - sum_{k<j} L[j,k]^2)
        rowj = mp.map_limbs(lambda l: l[0], _dyn(lmat, (j, 0), (1, n)))
        maskk = rows < j
        rowj = mp.where(maskk, rowj, mp.zeros((n,), prec, dtype))
        s = mp.sum_(mp.mul(rowj, rowj), axis=0)
        ajj = mp.map_limbs(lambda l: l[0, 0], _dyn(lmat, (j, j), (1, 1)))
        d = mp.sqrt(mp.sub(ajj, s))
        # column below: L[i,j] = (A[i,j] - sum_k L[i,k] L[j,k]) / d
        colA = mp.map_limbs(lambda l: l[:, 0], _dyn(lmat, (0, j), (n, 1)))
        lik = mp.where(maskk[None, :], lmat, mp.zeros((n, n), prec, dtype))
        contrib = mp.sum_(
            mp.mul(lik, mp.map_limbs(lambda l: l[None, :], rowj)), axis=1)
        num = mp.sub(colA, contrib)
        col = mp.div(num, mp.broadcast_to(d, num.shape))
        below = rows > j
        diag = rows == j
        new = mp.from_limbs([
            jnp.where(below, cl, jnp.where(diag, dl, 0.0))
            for cl, dl in zip(mp.limbs(col), mp.limbs(d))
        ])
        sel = (rows == j)[None, :]
        return tuple(
            jnp.where(sel, nl[:, None], ol)
            for nl, ol in zip(mp.limbs(new), carry))

    out = jax.lax.fori_loop(0, n, step, tuple(a_limbs))
    return tuple(jnp.tril(l) for l in out)


def rpotrf(a):
    """Lower Cholesky factor in multi-limb arithmetic: A = L L^T."""
    return mp.from_limbs(_potrf(tuple(mp.limbs(a))))


def cholesky_solve(l, b):
    """Solve (L L^T) x = b."""
    y = rtrsm(l, b, lower=True, unit_diag=False)
    return rtrsm(l, y, lower=True, unit_diag=False, transpose_a=True)
