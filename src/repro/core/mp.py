"""Count-generic multi-limb arithmetic — one API over any limb count.

The precision ladder (DESIGN.md §8) has one rung per limb count: ``dd``
(2 limbs, ~106 mantissa bits over f64), ``td`` (3 limbs, ~159 bits) and
``qd`` (4 limbs, ~212 bits).  Algorithms above the arithmetic — blocked
LU, TRSM, Cholesky, the GEMM engine's pad/batch/shard plumbing, the Rgemm
epilogue — are identical at every rung; only the per-element ops differ.
This module is the seam in both directions:

  * **downward**, it owns the count-parametric limb-list kernel family
    (``renorm_list`` and the ``*_limbs`` recipes below, Priest/Hida-style
    expansions with CAMPARY branch-free renormalization).  Tier modules
    (``td.py``, ``qd.py``) are thin bindings of these recipes at a fixed
    count; ``dd.py`` keeps its specialized two-limb algorithms (Li add,
    Dekker mul, Karp sqrt) as the documented k == 2 fast path, bit-for-bit
    compatible with the generic family's contracts.
  * **upward**, it dispatches the tier-value API (``add``/``mul``/...) on
    the concrete value type, so callers are written once against ``mp.*``
    and gain every rung — including future ones — for free.  Adding a rung
    means: one entry in ``PRECISIONS``, one thin tier module.  No other
    layer may re-derive limb counts.

Two op families:

  * **arithmetic** (``add``/``mul``/``div``/``sqrt``/``sum_``/...) —
    forwarded to the tier module, which binds the generic recipes (or, for
    dd, its specialized EFT chains);
  * **structural** (``map_limbs``/``where``/``broadcast_to``/slicing) —
    applied limb-wise, since limbs are plain jnp arrays and shape surgery
    is precision-agnostic.

``PRECISIONS`` maps the plan-level precision names to limb counts; the GEMM
plan/autotune cache keys on the limb count so each tier tunes independently.
"""

from __future__ import annotations

import importlib
from typing import Sequence

import jax.numpy as jnp

from .efts import quick_two_sum, two_prod_terms, two_sum

__all__ = [
    "PRECISIONS", "nlimbs", "precision_of", "precision_for_count", "limbs",
    "from_limbs", "map_limbs", "from_float", "zeros", "to_float", "promote",
    "add", "sub", "neg", "abs_", "mul", "mul_float", "fma", "div", "sqrt",
    "where", "sum_", "dot", "broadcast_to", "eps", "max_abs", "is_zero",
    # count-generic limb-list kernels (tier modules bind these; kernels and
    # the Ozaki recombination distill through them directly)
    "renorm_list", "add_limbs", "neg_limbs", "mul_limbs", "mul_float_limbs",
    "mul_pow2_limbs", "fma_limbs", "div_limbs", "sqrt_limbs", "sum_limbs",
    "to_dd_limbs", "eps_for",
]

# precision name -> limb count, in ladder order (cheapest rung first).
# Each name resolves to a tier module of the same name in this package
# whose value type is the upper-cased name (dd.DD, td.TD, qd.QD).
PRECISIONS = {"dd": 2, "td": 3, "qd": 4}

_BY_COUNT = {n: name for name, n in PRECISIONS.items()}

_MODS: dict = {}


def _tier_mod(precision: str):
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {sorted(PRECISIONS)}")
    mod = _MODS.get(precision)
    if mod is None:
        # lazy: tier modules import the generic kernels from here, so this
        # module must never import them at top level
        mod = importlib.import_module(f".{precision}", __package__)
        _MODS[precision] = mod
    return mod


def _tier_type(precision: str):
    return getattr(_tier_mod(precision), precision.upper())


def precision_for_count(n: int) -> str:
    """Precision name for a limb count (the inverse of ``PRECISIONS``)."""
    name = _BY_COUNT.get(n)
    if name is None:
        raise ValueError(f"unsupported limb count {n} "
                         f"(supported: {sorted(_BY_COUNT)})")
    return name


def precision_of(x) -> str:
    if isinstance(x, tuple) and hasattr(x, "limbs"):
        name = _BY_COUNT.get(len(x))
        if name is not None and isinstance(x, _tier_type(name)):
            return name
    raise TypeError(f"not a multi-limb value: {type(x).__name__}")


def _mod(x):
    return _tier_mod(precision_of(x))


def _mod2(a, *others):
    """Dispatch module for a binary/ternary op, rejecting mixed tiers.

    The count-generic limb kernels would happily concatenate a td and a qd
    limb list and renormalize to the FIRST operand's count — value-correct
    but a silent precision decision.  Mixing tiers must be an explicit
    ``promote``.  Non-tier operands (plain scalars/arrays) pass through for
    the tier module to coerce or reject itself.
    """
    pa = precision_of(a)
    for o in others:
        if isinstance(o, tuple) and hasattr(o, "limbs"):
            po = precision_of(o)
            if po != pa:
                raise TypeError(
                    f"mixed precision tiers: {pa!r} and {po!r} "
                    f"(mp.promote one operand explicitly)")
    return _tier_mod(pa)


def nlimbs(x) -> int:
    return PRECISIONS[precision_of(x)]


def limbs(x) -> list:
    """Limb arrays, most-significant first."""
    precision_of(x)  # type check
    return x.limbs()


def from_limbs(ls):
    """Rebuild a tier value from its limb list (count picks the tier)."""
    ls = list(ls)
    return _tier_type(precision_for_count(len(ls)))(*ls)


def map_limbs(f, x):
    """Apply a structural (shape-only) function to every limb."""
    return from_limbs([f(l) for l in limbs(x)])


def from_float(x, precision: str = "dd", dtype=None):
    return _tier_mod(precision).from_float(x, dtype=dtype)


def zeros(shape, precision: str = "dd", dtype=jnp.float64):
    return _tier_mod(precision).zeros(shape, dtype=dtype)


def to_float(x):
    return _mod(x).to_float(x)


def promote(x, precision: str):
    """Re-tier a value: climbing pads zero limbs (exact); descending
    distills the limb list to the narrower count (value-preserving sweeps,
    one rounding at the truncation — the multi-limb analogue of a
    round-to-nearest narrowing)."""
    kt = PRECISIONS.get(precision)
    if kt is None:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {sorted(PRECISIONS)}")
    cur = precision_of(x)
    if cur == precision:
        return x
    ls = limbs(x)
    if kt > len(ls):
        z = jnp.zeros_like(ls[0])
        return from_limbs(ls + [z] * (kt - len(ls)))
    return from_limbs(renorm_list(ls, k=kt))


def add(a, b):
    return _mod2(a, b).add(a, b)


def sub(a, b):
    return _mod2(a, b).sub(a, b)


def neg(a):
    return _mod(a).neg(a)


def abs_(a):
    return _mod(a).abs_(a)


def max_abs(a):
    """max |a| as an f64 scalar (the Rlange 'M' norm), traceable.

    The leading limb alone decides the magnitude ordering of a normalized
    expansion, and the lower limbs sit below its ulp — so the f64 value of
    the max-|entry| is exactly the max of |hi|.
    """
    return jnp.max(jnp.abs(limbs(a)[0]))


def is_zero(x):
    """Traced bool (elementwise): every limb of ``x`` is exactly zero.

    The single source for "is this tier value zero" — the engine's BLAS
    ``beta == 0`` guard and the fused kernel drain both key on it, so a
    future change to the zero encoding lands in one place.
    """
    z = None
    for l in limbs(x):
        e = l == 0
        z = e if z is None else jnp.logical_and(z, e)
    return z


def mul(a, b):
    return _mod2(a, b).mul(a, b)


def fma(acc, a, b):
    """acc + a*b — the multiply-add "PE" operation at acc's tier."""
    return _mod2(acc, a, b).fma(acc, a, b)


def mul_float(a, s):
    return _mod(a).mul_float(a, s)


def div(a, b):
    return _mod2(a, b).div(a, b)


def sqrt(a):
    return _mod(a).sqrt(a)


def where(c, a, b):
    return _mod(a).where(c, a, b)


def sum_(a, axis=None, keepdims=False):
    return _mod(a).sum_(a, axis=axis, keepdims=keepdims)


def dot(a, b):
    return _mod2(a, b).dot(a, b)


def broadcast_to(x, shape):
    return map_limbs(lambda l: jnp.broadcast_to(l, shape), x)


def eps_for(k: int, dtype=jnp.float64) -> float:
    """Unit roundoff of a k-limb expansion: 2^(-k*p) for p-bit limbs."""
    p = 53 if jnp.dtype(dtype) == jnp.float64 else 24
    return 2.0 ** (-k * p)


def eps(precision: str, dtype=jnp.float64) -> float:
    """Unit roundoff of a tier: 2^-2p (dd), 2^-3p (td), 2^-4p (qd)."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}")
    return eps_for(PRECISIONS[precision], dtype)


# --------------------------------------------------------------------------
# Count-generic limb-list kernel family.
#
# Everything below operates on plain python lists of limb arrays (most-
# significant first) with the count inferred from the list length, and
# imports nothing above efts — the tier modules bind these at a fixed k.
# The recipes reduce exactly to the historical qd algorithms at k == 4
# (same EFT sequence, hence bit-identical results), and td (k == 3) is the
# proof that no recipe secretly assumes a count.
#
# We use CAMPARY-style *branch-free* renormalization (bottom-up two_sum
# sweeps followed by top-down compression) rather than the branchy
# QD-library renormalize: data-dependent branches do not vectorize in JAX.
# The sweeps are value-preserving (every step is an EFT); only the final
# truncation to k limbs rounds.  Per-count accuracy is property-tested
# (tests/test_qd.py, tests/test_td.py): observed ~2^-200 relative error
# for qd64 chains, ~2^-150 for td64 — both comfortably past their formats'
# nominal 2^(-k*53+53) working targets.
# --------------------------------------------------------------------------


def _vecsum_bottom_up(limbs: Sequence) -> list:
    """Bottom-up two_sum sweep: pushes the dominant mass into limb 0.

    Exact: the multiset of limbs keeps the same total value.
    """
    out = [None] * len(limbs)
    s = limbs[-1]
    for i in range(len(limbs) - 2, -1, -1):
        s, e = two_sum(limbs[i], s)
        out[i + 1] = e
    out[0] = s
    return out


def _compress_top_down(limbs: Sequence) -> list:
    """Top-down two_sum sweep: each error drops to the next slot. Exact."""
    acc = limbs[0]
    out = []
    for i in range(1, len(limbs)):
        acc, err = two_sum(acc, limbs[i])
        out.append(err)
    return [acc] + out


def renorm_list(terms: Sequence, k: int = 4, sweeps: int = 3) -> list:
    """Distill an arbitrary list of floats into a k-limb expansion.

    Alternating exact sweeps converge the list toward a non-overlapping
    expansion; after the final sweep the tail beyond k limbs is folded into
    limb k-1 with ordinary (rounding) adds.
    """
    limbs = list(terms)
    for _ in range(sweeps):
        limbs = _vecsum_bottom_up(limbs)
        limbs = _compress_top_down(limbs)
    head, tail = limbs[: k - 1], limbs[k - 1 :]
    last = tail[-1]
    for t in reversed(tail[:-1]):
        last = last + t
    head.append(last)
    # final canonicalizing pass
    head = _compress_top_down(_vecsum_bottom_up(head))
    return head


def add_limbs(al: Sequence, bl: Sequence) -> list:
    """k-limb + k-limb: distill the concatenated expansions."""
    al, bl = list(al), list(bl)
    return renorm_list(al + bl, k=len(al), sweeps=3)


def neg_limbs(al: Sequence) -> list:
    return [-l for l in al]


def mul_limbs(al: Sequence, bl: Sequence) -> list:
    """Sloppy k-limb multiply: exact partial products through O(eps^(k-1)).

    Limb products for orders < k-1 use the exact term decomposition
    (two_prod_terms) so the distilled result carries no two_prod slack;
    order-(k-1) terms are plain (inexact) products, which is fine at
    O(eps^k).
    """
    al, bl = list(al), list(bl)
    k = len(al)
    terms = []
    for i in range(k):
        for j in range(k):
            o = i + j
            if o < k - 1:
                terms.extend(two_prod_terms(al[i], bl[j]))
            elif o == k - 1:
                terms.append(al[i] * bl[j])
    return renorm_list(terms, k=k, sweeps=3)


def mul_float_limbs(al: Sequence, b) -> list:
    """k-limb * plain-float array.  Exact partial products through limb
    k-2, distilled; cheaper than lifting ``b`` to k limbs for a full
    ``mul_limbs``."""
    al = list(al)
    b = jnp.asarray(b, al[0].dtype)
    terms = []
    for l in al[:-1]:
        terms.extend(two_prod_terms(l, b))
    terms.append(al[-1] * b)
    return renorm_list(terms, k=len(al), sweeps=3)


def mul_pow2_limbs(al: Sequence, s) -> list:
    """Exact scaling by a power of two."""
    return [l * s for l in al]


def fma_limbs(acc: Sequence, al: Sequence, bl: Sequence) -> list:
    return add_limbs(list(acc), mul_limbs(al, bl))


def div_limbs(al: Sequence, bl: Sequence) -> list:
    """Long division at k limbs: k+1 native-quotient correction rounds.

    Each round contributes ~53 bits of quotient (q_i = r[0] / b[0], then
    the remainder is updated exactly-ish via ``mul_float_limbs``), so k+1
    rounds overshoot the k*53-bit format; the distilled q_i are the
    result.  Branch free, like everything in this module.
    """
    al, bl = list(al), list(bl)
    k = len(al)
    q_terms = []
    r = al
    for _ in range(k + 1):
        qi = r[0] / bl[0]
        q_terms.append(qi)
        r = add_limbs(r, neg_limbs(mul_float_limbs(bl, qi)))
    return renorm_list(q_terms, k=k, sweeps=3)


def to_dd_limbs(ls: Sequence):
    """(hi, lo) double-word rounding of a k-limb expansion."""
    ls = list(ls)
    s, e = quick_two_sum(ls[0], ls[1])
    if len(ls) > 2:
        tail = ls[2]
        for t in ls[3:]:
            tail = tail + t
        e = e + tail
    return quick_two_sum(s, e)


def sqrt_limbs(al: Sequence) -> list:
    """k-limb sqrt: DD seed (~106 bits) + one Heron step s <- (s + a/s)/2.

    Newton doubles the correct bits, so one step lands at ~212 — at or
    past the capacity of every supported count (k <= 4).  Zero is guarded
    (the seed's 1/sqrt would inf*0 -> nan).
    """
    from . import dd as _dd

    al = list(al)
    k = len(al)
    sd = _dd.sqrt(_dd.DD(*to_dd_limbs(al)))
    z = jnp.zeros_like(al[0])
    s0 = [sd.hi, sd.lo] + [z] * (k - 2)
    s = mul_pow2_limbs(add_limbs(s0, div_limbs(al, s0)), 0.5)
    zero = al[0] == 0
    return [jnp.where(zero, jnp.zeros_like(l), l) for l in s]


def sum_limbs(al: Sequence, axis=None, keepdims=False) -> list:
    """Compensated reduction along an axis by repeated halving (every
    partial stays a full k-limb expansion, mirroring dd.sum_)."""
    al = list(al)
    if axis is None:
        return sum_limbs([l.reshape(-1) for l in al], axis=0,
                         keepdims=keepdims)
    cur = [jnp.moveaxis(l, axis, 0) for l in al]
    m = cur[0].shape[0]
    while m > 1:
        half = m // 2
        even = [l[: 2 * half : 2] for l in cur]
        odd = [l[1 : 2 * half : 2] for l in cur]
        red = add_limbs(even, odd)
        if m % 2:
            tail = [jnp.concatenate([l[-1:], jnp.zeros_like(r[1:])], 0)
                    for l, r in zip(cur, red)]
            red = add_limbs(red, tail)
        cur = red
        m = half
    out = [l[0] for l in cur]
    if keepdims:
        out = [jnp.expand_dims(l, axis) for l in out]
    return out
