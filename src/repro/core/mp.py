"""Limb-count-generic multi-limb arithmetic — one API over DD and QD.

The precision ladder (DESIGN.md §8) has one rung per limb count: ``dd``
(2 limbs, ~106 mantissa bits over f64) and ``qd`` (4 limbs, ~212 bits).
Algorithms above the arithmetic — blocked LU, TRSM, Cholesky, the GEMM
engine's pad/batch/shard plumbing, the Rgemm epilogue — are identical at
every rung; only the per-element ops differ.  This module is the seam: it
dispatches on the concrete value type (``dd.DD`` | ``qd.QD``), so those
layers are written once against ``mp.*`` and gain every future tier (df32
QD on TPU, octuple) for free.

Two op families:

  * **arithmetic** (``add``/``mul``/``div``/``sqrt``/``sum_``/...) —
    forwarded to the tier module, which owns the error-free transformations;
  * **structural** (``map_limbs``/``where``/``broadcast_to``/slicing) —
    applied limb-wise, since limbs are plain jnp arrays and shape surgery
    is precision-agnostic.

``PRECISIONS`` maps the plan-level precision names to limb counts; the GEMM
plan/autotune cache keys on the limb count so each tier tunes independently.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import dd, qd

__all__ = [
    "PRECISIONS", "nlimbs", "precision_of", "limbs", "from_limbs",
    "map_limbs", "from_float", "zeros", "to_float", "promote",
    "add", "sub", "neg", "abs_", "mul", "mul_float", "div", "sqrt",
    "where", "sum_", "dot", "broadcast_to", "eps", "max_abs", "is_zero",
]

PRECISIONS = {"dd": 2, "qd": 4}


def _mod(x):
    if isinstance(x, dd.DD):
        return dd
    if isinstance(x, qd.QD):
        return qd
    raise TypeError(f"not a multi-limb value: {type(x).__name__}")


def nlimbs(x) -> int:
    return len(_mod_limbs(x))


def _mod_limbs(x):
    _mod(x)  # type check
    return x.limbs()


def precision_of(x) -> str:
    return "dd" if isinstance(x, dd.DD) else (
        "qd" if isinstance(x, qd.QD) else _raise(x))


def _raise(x):
    raise TypeError(f"not a multi-limb value: {type(x).__name__}")


def limbs(x) -> list:
    """Limb arrays, most-significant first."""
    return _mod_limbs(x)


def from_limbs(ls):
    """Rebuild a tier value from its limb list (2 -> DD, 4 -> QD)."""
    ls = list(ls)
    if len(ls) == 2:
        return dd.DD(*ls)
    if len(ls) == 4:
        return qd.QD(*ls)
    raise ValueError(f"unsupported limb count {len(ls)} (want 2 or 4)")


def map_limbs(f, x):
    """Apply a structural (shape-only) function to every limb."""
    return from_limbs([f(l) for l in limbs(x)])


def from_float(x, precision: str = "dd", dtype=None):
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {sorted(PRECISIONS)}")
    mod = dd if precision == "dd" else qd
    return mod.from_float(x, dtype=dtype)


def zeros(shape, precision: str = "dd", dtype=jnp.float64):
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}")
    return (dd if precision == "dd" else qd).zeros(shape, dtype=dtype)


def to_float(x):
    return _mod(x).to_float(x)


def promote(x, precision: str):
    """Re-tier a value: dd -> qd pads zero limbs (exact); qd -> dd rounds."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {sorted(PRECISIONS)}")
    cur = precision_of(x)
    if cur == precision:
        return x
    return qd.from_dd(x) if precision == "qd" else qd.to_dd(x)


def add(a, b):
    return _mod(a).add(a, b)


def sub(a, b):
    return _mod(a).sub(a, b)


def neg(a):
    return _mod(a).neg(a)


def abs_(a):
    return _mod(a).abs_(a)


def max_abs(a):
    """max |a| as an f64 scalar (the Rlange 'M' norm), traceable.

    The leading limb alone decides the magnitude ordering of a normalized
    expansion, and the lower limbs sit below its ulp — so the f64 value of
    the max-|entry| is exactly the max of |hi|.
    """
    return jnp.max(jnp.abs(limbs(a)[0]))


def is_zero(x):
    """Traced bool (elementwise): every limb of ``x`` is exactly zero.

    The single source for "is this tier value zero" — the engine's BLAS
    ``beta == 0`` guard and the fused kernel drain both key on it, so a
    future change to the zero encoding lands in one place.
    """
    z = None
    for l in limbs(x):
        e = l == 0
        z = e if z is None else jnp.logical_and(z, e)
    return z


def mul(a, b):
    return _mod(a).mul(a, b)


def mul_float(a, s):
    return _mod(a).mul_float(a, s)


def div(a, b):
    return _mod(a).div(a, b)


def sqrt(a):
    return _mod(a).sqrt(a)


def where(c, a, b):
    return _mod(a).where(c, a, b)


def sum_(a, axis=None, keepdims=False):
    return _mod(a).sum_(a, axis=axis, keepdims=keepdims)


def dot(a, b):
    return _mod(a).dot(a, b)


def broadcast_to(x, shape):
    return map_limbs(lambda l: jnp.broadcast_to(l, shape), x)


def eps(precision: str, dtype=jnp.float64) -> float:
    """Unit roundoff of a tier: 2^-2p for dd, 2^-4p for qd."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}")
    return (dd if precision == "dd" else qd).eps(dtype)
