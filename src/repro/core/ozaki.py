"""Ozaki-scheme GEMM: binary128-class matmul out of *native* GEMMs.

This is the TPU-codesign counterpart of the paper's custom binary128 MACs
(DESIGN.md §2, beyond-paper path).  The FPGA builds a wide multiplier out of
DSP blocks; the TPU's native wide-throughput unit is the MXU systolic array
(bf16 x bf16 -> f32 at 197 TFLOP/s on v5e).  The Ozaki scheme [Ozaki et al.
2012; Mukunoki et al. ICPP'21, cited by the paper] decomposes each operand
into *error-free slices* such that every slice-pair GEMM is exact in the
accumulator precision; the slice products are then recombined into a
multi-limb result.

Slice extraction per row of A / column of B (Rump/Ozaki error-free split):

    w   = 2^(ceil(log2 max|row|) + beta)        # fixed-point grid
    S   = (x + w) - w                           # top beta bits, EXACT
    x  <- x - S                                 # exact remainder

Recombination is *diagonal-grouped* (DESIGN.md §9): slice products with
equal significance level d = s + t all live on one fixed-point grid, so the
whole diagonal is summed in the native accumulator FIRST — the d+1 pair
GEMMs and their sum — and only then folded into the multi-limb result.
That cuts the number of full-matrix multi-limb adds from ~s^2/2 (one per
slice pair) to s (one per diagonal): on CPU at n=256 the dd recombination
drops from 21 `dd.add` passes over HBM-resident matrices to 6 cheap
`add_float` folds, a measured ~3x end-to-end win (BENCH_GEMM.json).

Exactness condition, grouped form: a diagonal sums up to n_slices pair
products of k terms each, so

    2*beta + ceil(log2 k) + ceil(log2 n_slices) <= p_acc

guarantees every partial sum of the diagonal — inside each pair dot and
across the d+1 dot results — is exactly representable (all summands are
integer multiples of the diagonal's common grid and the running sum never
exceeds 2^p_acc grid units — true in any summation order, so XLA/MXU
reduction trees are covered).  ``slice_params`` solves this fixpoint (beta
depends on n_slices, n_slices on beta) once; the GEMM plan layer calls it
and carries (beta, n_slices) so kernels never re-derive slice parameters.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import dd, mp

__all__ = ["ozaki_gemm", "slice_count", "slice_bits", "slice_params",
           "platform_dtypes"]


def platform_dtypes(platform: str):
    """(slice_dtype, acc_dtype) riding the platform's native GEMM unit.

    TPU: bf16 slices accumulated in f32 on the MXU (the beyond-paper path);
    everywhere else f64/f64, where XLA's native dot is already the fast unit.
    Consumed by the plan layer (repro.gemm.make_plan) so call sites never
    hand-pick slice dtypes.
    """
    if platform == "tpu":
        return jnp.bfloat16, jnp.float32
    return jnp.float64, jnp.float64


def slice_bits(k: int, acc_dtype, slice_dtype=None, group: int = 1) -> int:
    """Max bits per slice for exact accumulation over a k-deep GEMM.

    ``group`` is the number of same-diagonal pair products summed in the
    native accumulator before the multi-limb fold (1 = the ungrouped
    pair-at-a-time scheme); the grouped scheme needs ceil(log2 group) bits
    of extra headroom per the exactness condition in the module docstring.
    """
    p_acc = {jnp.dtype(jnp.float64): 53, jnp.dtype(jnp.float32): 24}[jnp.dtype(acc_dtype)]
    head = math.ceil(math.log2(max(k, 2)))
    if group > 1:
        head += math.ceil(math.log2(group))
    beta = (p_acc - head) // 2
    if slice_dtype is not None and jnp.dtype(slice_dtype) == jnp.dtype(jnp.bfloat16):
        beta = min(beta, 8)  # bf16 mantissa (incl. implicit bit)
    if beta < 1:
        raise ValueError(f"k={k} too deep for exact slicing in {jnp.dtype(acc_dtype).name}")
    return beta


def slice_count(target_bits: int, beta: int) -> int:
    """Slices per operand to cover target_bits of significand."""
    return math.ceil(target_bits / beta) + 1


def slice_params(k: int, acc_dtype, slice_dtype=None, *,
                 target_bits: int = 107, n_slices: int | None = None,
                 beta: int | None = None,
                 guard_bits: int = 4) -> tuple[int, int]:
    """Solve (beta, n_slices) for the diagonal-grouped scheme — the single
    source of slice parameters (``repro.gemm.make_plan`` stores the result
    on the plan; kernels consume it, never re-derive it).

    beta and n_slices are mutually dependent: summing a diagonal of up to
    n_slices pair products in the native accumulator costs ceil(log2
    n_slices) headroom bits, which shrinks beta, which raises the slice
    count needed to cover ``target_bits`` (+ log2 k for the k-fold
    truncation-error growth, + guard bits).  A short fixpoint iteration
    converges in 2-3 steps.  Either parameter may be pinned by the caller
    (the other is solved for it; pinning both is an identity).  Raises
    ValueError when k is too deep for any exact slicing in ``acc_dtype``
    (planners catch this and fall back).
    """
    need = target_bits + math.ceil(math.log2(max(k, 2))) + guard_bits
    if beta is not None:
        # pinned beta: solve (or accept) the count, then VALIDATE — a beta
        # past the grouping-headroom ceiling silently breaks the exact
        # native summation, which is the one invariant of the scheme
        s = n_slices if n_slices is not None \
            else max(2, math.ceil(need / beta))
        limit = slice_bits(k, acc_dtype, slice_dtype, group=s)
        if beta > limit:
            raise ValueError(
                f"beta={beta} violates exact accumulation for k={k}, "
                f"n_slices={s} in {jnp.dtype(acc_dtype).name} "
                f"(max {limit}: 2*beta + log2(k*n_slices) must fit p_acc)")
        return beta, s
    if n_slices is not None:
        # pinned slice count: beta just honors the grouping headroom
        return slice_bits(k, acc_dtype, slice_dtype, group=n_slices), n_slices
    s = max(2, math.ceil(need / slice_bits(k, acc_dtype, slice_dtype)))
    for _ in range(16):
        beta = slice_bits(k, acc_dtype, slice_dtype, group=s)
        s_next = max(2, math.ceil(need / beta))
        if s_next == s:
            break
        s = s_next
    return beta, s


def _extract_slices(x, beta: int, n_slices: int, axis: int):
    """Error-free slice extraction along rows (axis=1, for A) or cols (axis=0).

    Rump's ExtractVector: with row/col magnitude mu < 2^e and anchor
    sigma = 2^(e + p - beta), S = fl(r + sigma) - sigma rounds r to the grid
    2^(e+1-beta) — i.e. S carries the top ~beta bits, exactly, and r - S is
    exact.  The anchor ladder is FIXED from the initial row/col magnitude
    (sigma_i = sigma_0 * 2^(-i*beta)) rather than re-derived from each
    residual: slice i of every row then sits exactly on the grid
    2^(e+1-(i+1)*beta), so any two slice products with equal s + t share
    one fixed-point grid — the property the diagonal-grouped native
    summation's exactness proof needs (an adaptive re-anchor can drop a
    row's grid arbitrarily low after cancellation, silently widening the
    diagonal's span past p_acc).  Coverage is unchanged: the residual
    after i steps is < 2^(e+1-i*beta) either way.

    ``x`` is any multi-limb value (dd.DD or qd.QD — the residual
    subtraction runs in the value's own tier, so lower limbs surface in
    later slices).  Returns a list of limb-dtype matrices, each <= beta
    significant bits per entry on the per-row/col grid ladder.
    """
    lead = mp.limbs(x)[0]
    pbits = 53 if jnp.dtype(lead.dtype) == jnp.float64 else 24
    prec = mp.precision_of(x)
    mu = jnp.max(jnp.abs(lead), axis=axis, keepdims=True)
    # sigma = 2^(exponent(mu) + pbits - beta), built from exact
    # power-of-two primitives (xla:cpu log2/exp2 are approximate)
    sigma = _pow2_near(mu) * (2.0 ** (pbits - beta))
    nonzero = mu > 0
    slices = []
    r = x
    for _ in range(n_slices):
        hi = mp.limbs(r)[0]
        s = jnp.where(nonzero, (hi + sigma) - sigma, 0.0)
        slices.append(s)
        r = mp.sub(r, mp.from_float(s, prec))
        sigma = sigma * (2.0 ** -beta)
    return slices


def _pow2_near(mu):
    """Exact power of two ~mu: mu / mantissa(mu) == 2^exponent(mu), exactly."""
    # the floor keeps frexp off zero without ever over/underflowing the
    # limb dtype (2^-511 is not representable in f32)
    floor = 2.0 ** -511 if jnp.dtype(mu.dtype) == jnp.float64 else 2.0 ** -63
    mu = jnp.maximum(mu, floor)
    m, _ = jnp.frexp(mu)  # mu = m * 2^e, m in [0.5, 1)
    return mu / m


def _diagonal_pairs(d: int, n_slices: int):
    """(s, t) slice pairs on diagonal d = s + t, most-significant A first."""
    return [(i, d - i) for i in range(max(0, d - n_slices + 1),
                                      min(d + 1, n_slices))]


def _normalize_slices(slices, beta: int, axis: int, slice_dtype):
    """Ladder-normalize slices into a narrow dtype, EXACTLY.

    Slice i is scaled by 2^(i*beta) / sc — the inverse of its own rung of
    the extraction ladder — so every slice lands at O(1) per row/col
    regardless of how deep the ladder goes (a single shared scale would
    leave slice i at relative magnitude 2^(-i*beta), which underflows
    bf16/f32 for the qd-depth ladders).  All factors are exact powers of
    two, so grid alignment survives: the product of A-slice s and B-slice
    t carries the residual factor 2^(-(s+t)*beta), i.e. one rescale of
    sc_a * sc_b * 2^(-d*beta) per DIAGONAL, which is what lets a whole
    diagonal still accumulate natively and rescale once.

    Returns (scaled slices, sc).
    """
    sc = _pow2_near(jnp.max(jnp.abs(slices[0]), axis=axis, keepdims=True))
    return [((s * (2.0 ** (i * beta))) / sc).astype(slice_dtype)
            for i, s in enumerate(slices)], sc


def _fold_diagonal_sum(acc, dsum):
    """acc += one diagonal's native-dtype sum, in acc's own tier.

    dd keeps its cheap ``add_float`` fold; wider counts distill the
    (k+1)-term list — cheaper than lifting ``dsum`` to a full tier add.
    """
    if isinstance(acc, dd.DD):
        return dd.add_float(acc, dsum)
    k = len(acc.limbs())
    return mp.from_limbs(
        mp.renorm_list(list(acc.limbs()) + [dsum], k=k, sweeps=3))


@partial(jax.jit, static_argnames=("slice_dtype_name", "acc_dtype_name",
                                   "n_slices", "beta", "full"))
def _ozaki_impl(*ab_limbs, slice_dtype_name: str,
                acc_dtype_name: str, n_slices: int, beta: int, full: bool):
    slice_dtype = jnp.dtype(slice_dtype_name)
    acc_dtype = jnp.dtype(acc_dtype_name)
    nlimbs = len(ab_limbs) // 2
    a = mp.from_limbs(ab_limbs[:nlimbs])
    b = mp.from_limbs(ab_limbs[nlimbs:])
    limb_dtype = ab_limbs[0].dtype
    sa = _extract_slices(a, beta, n_slices, axis=1)
    sb = _extract_slices(b, beta, n_slices, axis=0)

    narrow = jnp.dtype(slice_dtype) != jnp.dtype(limb_dtype)
    if narrow:
        # exact ladder normalization into the narrow dtype (the scales are
        # exact powers of two: xla:cpu's log2 is approximate under jit, so
        # _pow2_near derives them as mu / frexp_mantissa(mu) instead)
        sa, sc_a = _normalize_slices(sa, beta, 1, slice_dtype)
        sb, sc_b = _normalize_slices(sb, beta, 0, slice_dtype)

    m, n = mp.limbs(a)[0].shape[0], mp.limbs(b)[0].shape[1]
    acc = mp.zeros((m, n), mp.precision_of(a), dtype=limb_dtype)
    # diagonal-grouped recombination, most-significant diagonal first: the
    # d+1 pair dots of diagonal d sum in acc_dtype — exact by the
    # slice_params headroom — then ONE multi-limb fold per diagonal instead
    # of one per slice pair.  (Separate pair dots beat one concatenated
    # (m,(d+1)k) dot on xla:cpu by ~2.5x: the concat copies defeat the
    # contraction's fast path; the summation is exact either way.)
    n_diag = (2 * n_slices - 1) if full else n_slices
    for d in range(n_diag):
        dsum = None
        for s, t in _diagonal_pairs(d, n_slices):
            p = jnp.dot(sa[s], sb[t], preferred_element_type=acc_dtype)
            dsum = p if dsum is None else dsum + p
        if narrow:
            dsum = dsum.astype(limb_dtype) * \
                (sc_a * sc_b * (2.0 ** (-d * beta)))
        acc = _fold_diagonal_sum(acc, dsum.astype(limb_dtype))
    return tuple(mp.limbs(acc))


def ozaki_gemm(a, b, *, slice_dtype=None, acc_dtype=None,
               n_slices: int | None = None, beta: int | None = None,
               target_bits: int = 107, full: bool = False):
    """C = A @ B via error-free slicing onto native GEMMs.

    ``a``/``b`` may carry any registered limb count (the slice ladder just
    runs deeper for wider tiers; the default ``target_bits`` covers dd —
    pass the tier's own target, e.g. 159 for td, for wider operands).
    Defaults: f64 slices + f64 accumulation (CPU validation path).  On TPU
    pass slice_dtype=jnp.bfloat16, acc_dtype=jnp.float32 to ride the MXU.
    When called through the engine, (beta, n_slices) come from the plan
    (``make_plan`` solved them via ``slice_params``); standalone callers
    get them solved here, once.
    """
    acc_dtype = acc_dtype or jnp.float64
    slice_dtype = slice_dtype or jnp.float64
    k = mp.limbs(a)[0].shape[1]
    beta, n_slices = slice_params(k, acc_dtype, slice_dtype,
                                  target_bits=target_bits,
                                  n_slices=n_slices, beta=beta)
    out = _ozaki_impl(
        *mp.limbs(a), *mp.limbs(b),
        slice_dtype_name=jnp.dtype(slice_dtype).name,
        acc_dtype_name=jnp.dtype(acc_dtype).name,
        n_slices=n_slices, beta=beta, full=full,
    )
    return mp.from_limbs(out)
