"""Ozaki-scheme GEMM: binary128-class matmul out of *native* GEMMs.

This is the TPU-codesign counterpart of the paper's custom binary128 MACs
(DESIGN.md §2, beyond-paper path).  The FPGA builds a wide multiplier out of
DSP blocks; the TPU's native wide-throughput unit is the MXU systolic array
(bf16 x bf16 -> f32 at 197 TFLOP/s on v5e).  The Ozaki scheme [Ozaki et al.
2012; Mukunoki et al. ICPP'21, cited by the paper] decomposes each operand
into *error-free slices* such that every slice-pair GEMM is exact in the
accumulator precision; the slice products are then recombined with two_sum
chains into a double-word result.  binary128 GEMM thus becomes ~s(s+1)/2
native GEMMs — on the MXU that is ~1.1 TFLOP/s effective binary128, an order
of magnitude past the paper's 90.9 GFlops Agilex design (EXPERIMENTS.md).

Slice extraction per row of A / column of B (Rump/Ozaki error-free split):

    w   = 2^(ceil(log2 max|row|) + beta)        # fixed-point grid
    S   = (x + w) - w                           # top beta bits, EXACT
    x  <- x - S                                 # exact remainder

Exactness condition: 2*beta + ceil(log2 k) <= p_acc, so every product of a
beta-bit A-slice with a beta-bit B-slice accumulates exactly over k terms in
the p_acc-bit accumulator.  With bf16 slices (p=8) and f32 accumulation
(p=24), beta = min(8, (24 - ceil(log2 k)) // 2); with f64 slices/accumulator
(the CPU validation path), beta = (53 - ceil(log2 k)) // 2.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import dd

__all__ = ["ozaki_gemm", "slice_count", "slice_bits", "platform_dtypes"]


def platform_dtypes(platform: str):
    """(slice_dtype, acc_dtype) riding the platform's native GEMM unit.

    TPU: bf16 slices accumulated in f32 on the MXU (the beyond-paper path);
    everywhere else f64/f64, where XLA's native dot is already the fast unit.
    Consumed by the plan layer (repro.gemm.make_plan) so call sites never
    hand-pick slice dtypes.
    """
    if platform == "tpu":
        return jnp.bfloat16, jnp.float32
    return jnp.float64, jnp.float64


def slice_bits(k: int, acc_dtype, slice_dtype=None) -> int:
    """Max bits per slice for exact accumulation over a k-deep GEMM."""
    p_acc = {jnp.dtype(jnp.float64): 53, jnp.dtype(jnp.float32): 24}[jnp.dtype(acc_dtype)]
    beta = (p_acc - math.ceil(math.log2(max(k, 2)))) // 2
    if slice_dtype is not None and jnp.dtype(slice_dtype) == jnp.dtype(jnp.bfloat16):
        beta = min(beta, 8)  # bf16 mantissa (incl. implicit bit)
    if beta < 1:
        raise ValueError(f"k={k} too deep for exact slicing in {acc_dtype}")
    return beta


def slice_count(target_bits: int, beta: int) -> int:
    """Slices per operand to cover target_bits of significand."""
    return math.ceil(target_bits / beta) + 1


def _extract_slices(x: dd.DD, beta: int, n_slices: int, axis: int):
    """Error-free slice extraction along rows (axis=1, for A) or cols (axis=0).

    Rump's ExtractVector: with row/col magnitude mu < 2^e and anchor
    sigma = 2^(e + p - beta), S = fl(r + sigma) - sigma rounds r to the grid
    2^(e+1-beta) — i.e. S carries the top ~beta bits, exactly, and r - S is
    exact.  Returns a list of limb-dtype matrices, each <= beta significant
    bits per entry on a per-row/col grid.
    """
    pbits = 53 if jnp.dtype(x.hi.dtype) == jnp.float64 else 24
    slices = []
    r = x
    for _ in range(n_slices):
        mu = jnp.max(jnp.abs(r.hi), axis=axis, keepdims=True)
        # sigma = 2^(exponent(mu) + pbits - beta), built from exact
        # power-of-two primitives (xla:cpu log2/exp2 are approximate)
        sigma = _pow2_near(mu) * (2.0 ** (pbits - beta))
        s = jnp.where(mu > 0, (r.hi + sigma) - sigma, 0.0)
        slices.append(s)
        r = dd.sub(r, dd.from_float(s))
    return slices


def _pow2_near(mu):
    """Exact power of two ~mu: mu / mantissa(mu) == 2^exponent(mu), exactly."""
    mu = jnp.maximum(mu, 2.0**-511)
    m, _ = jnp.frexp(mu)  # mu = m * 2^e, m in [0.5, 1)
    return mu / m


@partial(jax.jit, static_argnames=("slice_dtype_name", "acc_dtype_name", "n_slices", "full"))
def _ozaki_impl(a_hi, a_lo, b_hi, b_lo, *, slice_dtype_name: str,
                acc_dtype_name: str, n_slices: int, full: bool):
    slice_dtype = jnp.dtype(slice_dtype_name)
    acc_dtype = jnp.dtype(acc_dtype_name)
    a = dd.DD(a_hi, a_lo)
    b = dd.DD(b_hi, b_lo)
    k = a.hi.shape[1]
    beta = slice_bits(k, acc_dtype, slice_dtype)
    sa = _extract_slices(a, beta, n_slices, axis=1)
    sb = _extract_slices(b, beta, n_slices, axis=0)

    m, n = a.hi.shape[0], b.hi.shape[1]
    acc = dd.zeros((m, n), dtype=a.hi.dtype)
    # accumulate slice products most-significant first; (s, t) with
    # s + t >= n_slices contribute below the target precision (triangular
    # truncation) unless full=True
    order = sorted(
        ((s, t) for s in range(n_slices) for t in range(n_slices)
         if full or s + t < n_slices),
        key=lambda st: st[0] + st[1],
    )
    for s, t in order:
        if jnp.dtype(slice_dtype) != jnp.dtype(jnp.float64):
            # scale slices to O(1) per row/col so they fit the narrow
            # dtype's exponent/mantissa, multiply, and scale back.  The
            # scale must be an EXACT power of two: xla:cpu's log2 is
            # approximate under jit (floor(log2 2^k) can land on k-1), so
            # derive it as mu / frexp_mantissa(mu) — an exact IEEE division
            # with exactly-representable result.
            sc_a = _pow2_near(jnp.max(jnp.abs(sa[s]), axis=1, keepdims=True))
            sc_b = _pow2_near(jnp.max(jnp.abs(sb[t]), axis=0, keepdims=True))
            a_n = (sa[s] / sc_a).astype(slice_dtype)
            b_n = (sb[t] / sc_b).astype(slice_dtype)
            prod = jnp.dot(a_n, b_n, preferred_element_type=acc_dtype)
            prod = prod.astype(a.hi.dtype) * sc_a * sc_b
        else:
            prod = jnp.dot(sa[s], sb[t], preferred_element_type=acc_dtype)
        acc = dd.add(acc, dd.from_float(prod.astype(a.hi.dtype)))
    return acc.hi, acc.lo


def ozaki_gemm(a: dd.DD, b: dd.DD, *, slice_dtype=None, acc_dtype=None,
               n_slices: int | None = None, target_bits: int = 107,
               full: bool = False) -> dd.DD:
    """C = A @ B via error-free slicing onto native GEMMs.

    Defaults: f64 slices + f64 accumulation (CPU validation path).  On TPU
    pass slice_dtype=jnp.bfloat16, acc_dtype=jnp.float32 to ride the MXU.
    """
    acc_dtype = acc_dtype or jnp.float64
    slice_dtype = slice_dtype or jnp.float64
    k = a.hi.shape[1]
    beta = slice_bits(k, acc_dtype, slice_dtype)
    if n_slices is None:
        n_slices = slice_count(target_bits, beta)
    hi, lo = _ozaki_impl(
        a.hi, a.lo, b.hi, b.lo,
        slice_dtype_name=jnp.dtype(slice_dtype).name,
        acc_dtype_name=jnp.dtype(acc_dtype).name,
        n_slices=n_slices, full=full,
    )
    return dd.DD(hi, lo)
