"""Quad-word expansion arithmetic (4 limbs) — past-binary128 precision.

binary128 carries a 113-bit mantissa; dd64 (dd.py) carries ~106.  When the
extra 7 bits matter, ``QD`` over f64 limbs (~212 bits) strictly dominates
binary128; over f32 limbs (~98 bits) it is the widest VPU-native format that
avoids f64 entirely (TPU Pallas/Mosaic has no f64 path).

Every operation here is a thin binding of the count-parametric kernel
family in ``core/mp.py`` at k == 4 — the generic recipes are the same EFT
sequences this module used to carry inline (CAMPARY branch-free
renormalization, exact partial products through O(eps^3), five-round long
division, DD-seeded Heron sqrt), so results are bit-identical to the
pre-refactor code.  Empirical accuracy is property-tested in
tests/test_qd.py (observed ~2^-200 relative error for qd64 mul/add chains,
comfortably past binary128's 2^-113).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import mp as _mp
from .mp import renorm_list  # re-exported; kernels distill through it

__all__ = ["QD", "from_float", "from_dd", "to_float", "to_dd", "zeros",
           "add", "sub", "mul", "mul_float", "mul_pow2", "neg", "abs_",
           "fma", "div", "sqrt", "where", "sum_", "dot", "eps",
           "renorm_list"]


class QD(NamedTuple):
    x0: jnp.ndarray
    x1: jnp.ndarray
    x2: jnp.ndarray
    x3: jnp.ndarray

    @property
    def dtype(self):
        return self.x0.dtype

    @property
    def shape(self):
        return self.x0.shape

    def limbs(self):
        return [self.x0, self.x1, self.x2, self.x3]

    def __getitem__(self, idx):
        return QD(self.x0[idx], self.x1[idx], self.x2[idx], self.x3[idx])

    def reshape(self, *shape):
        return QD(*[l.reshape(*shape) for l in self.limbs()])


def eps(dtype) -> float:
    """Unit roundoff of the QD format with the given limb dtype."""
    return _mp.eps_for(4, dtype)


def from_float(x, dtype=None) -> QD:
    x = jnp.asarray(x, dtype=dtype)
    z = jnp.zeros_like(x)
    return QD(x, z, z, z)


def from_dd(x) -> QD:
    z = jnp.zeros_like(x.hi)
    return QD(x.hi, x.lo, z, z)


def to_float(q: QD):
    return ((q.x3 + q.x2) + q.x1) + q.x0


def to_dd(q: QD):
    from . import dd as _dd

    return _dd.DD(*_mp.to_dd_limbs(q.limbs()))


def zeros(shape, dtype=jnp.float64) -> QD:
    z = jnp.zeros(shape, dtype=dtype)
    return QD(z, z, z, z)


def neg(q: QD) -> QD:
    return QD(-q.x0, -q.x1, -q.x2, -q.x3)


def abs_(q: QD) -> QD:
    # the leading limb carries the sign of the whole expansion
    m = q.x0 < 0
    return QD(*[jnp.where(m, -l, l) for l in q.limbs()])


def where(c, a: QD, b: QD) -> QD:
    return QD(*[jnp.where(c, x, y) for x, y in zip(a.limbs(), b.limbs())])


def add(a: QD, b: QD) -> QD:
    return QD(*_mp.add_limbs(a.limbs(), b.limbs()))


def sub(a: QD, b: QD) -> QD:
    return add(a, neg(b))


def mul(a: QD, b: QD) -> QD:
    """Sloppy QD multiply: exact partial products through O(eps^3);
    order-3 terms are plain products (fine at O(eps^4))."""
    return QD(*_mp.mul_limbs(a.limbs(), b.limbs()))


def mul_float(a: QD, b) -> QD:
    """QD * plain-float array.  Exact partial products through limb 2,
    distilled; cheaper than lifting ``b`` to QD for a full ``mul``."""
    return QD(*_mp.mul_float_limbs(a.limbs(), b))


def mul_pow2(a: QD, s) -> QD:
    """Exact scaling by a power of two."""
    return QD(*_mp.mul_pow2_limbs(a.limbs(), s))


def fma(acc: QD, a: QD, b: QD) -> QD:
    return add(acc, mul(a, b))


def div(a: QD, b: QD) -> QD:
    """Long-division QD / QD: five native-quotient correction rounds (the
    generic k+1), overshooting the 212-bit format.  Branch free."""
    return QD(*_mp.div_limbs(a.limbs(), b.limbs()))


def sqrt(a: QD) -> QD:
    """QD sqrt: DD seed (~106 bits) + one Heron step s <- (s + a/s)/2.

    Newton doubles the correct bits, so one step lands at ~212 — the
    format's capacity.  Zero is guarded in the generic recipe.
    """
    return QD(*_mp.sqrt_limbs(a.limbs()))


def sum_(a: QD, axis=None, keepdims=False) -> QD:
    """Compensated reduction along an axis by repeated halving (every
    partial stays a full QD expansion, mirroring dd.sum_)."""
    return QD(*_mp.sum_limbs(a.limbs(), axis=axis, keepdims=keepdims))


def dot(a: QD, b: QD) -> QD:
    """Inner product of two QD vectors with QD accumulation."""
    return sum_(mul(a, b), axis=0)
