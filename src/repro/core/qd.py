"""Quad-word expansion arithmetic (4 limbs) — past-binary128 precision.

binary128 carries a 113-bit mantissa; dd64 (dd.py) carries ~106.  When the
extra 7 bits matter, ``QD`` over f64 limbs (~212 bits) strictly dominates
binary128; over f32 limbs (~98 bits) it is the widest VPU-native format that
avoids f64 entirely (TPU Pallas/Mosaic has no f64 path).

We use CAMPARY-style *branch-free* renormalization (bottom-up two_sum sweeps
followed by top-down compression) rather than the branchy QD-library
renormalize: data-dependent branches do not vectorize in JAX.  The sweeps are
value-preserving (every step is an EFT); only the final truncation to 4 limbs
rounds.  Empirical accuracy is property-tested in tests/test_qd.py (observed
~2^-200 relative error for qd64 mul/add chains, comfortably past binary128's
2^-113).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp

from .efts import quick_two_sum, two_prod_terms, two_sum

__all__ = ["QD", "from_float", "from_dd", "to_float", "to_dd", "zeros",
           "add", "sub", "mul", "mul_float", "mul_pow2", "neg", "abs_",
           "fma", "div", "sqrt", "where", "sum_", "dot", "eps",
           "renorm_list"]


class QD(NamedTuple):
    x0: jnp.ndarray
    x1: jnp.ndarray
    x2: jnp.ndarray
    x3: jnp.ndarray

    @property
    def dtype(self):
        return self.x0.dtype

    @property
    def shape(self):
        return self.x0.shape

    def limbs(self):
        return [self.x0, self.x1, self.x2, self.x3]

    def __getitem__(self, idx):
        return QD(self.x0[idx], self.x1[idx], self.x2[idx], self.x3[idx])

    def reshape(self, *shape):
        return QD(*[l.reshape(*shape) for l in self.limbs()])


def eps(dtype) -> float:
    """Unit roundoff of the QD format with the given limb dtype."""
    p = 53 if jnp.dtype(dtype) == jnp.float64 else 24
    return 2.0 ** (-4 * p)


def from_float(x, dtype=None) -> QD:
    x = jnp.asarray(x, dtype=dtype)
    z = jnp.zeros_like(x)
    return QD(x, z, z, z)


def from_dd(x) -> QD:
    z = jnp.zeros_like(x.hi)
    return QD(x.hi, x.lo, z, z)


def to_float(q: QD):
    return ((q.x3 + q.x2) + q.x1) + q.x0


def to_dd(q: QD):
    from . import dd as _dd

    s, e = quick_two_sum(q.x0, q.x1)
    return _dd.DD(*quick_two_sum(s, e + (q.x2 + q.x3)))


def zeros(shape, dtype=jnp.float64) -> QD:
    z = jnp.zeros(shape, dtype=dtype)
    return QD(z, z, z, z)


def neg(q: QD) -> QD:
    return QD(-q.x0, -q.x1, -q.x2, -q.x3)


def abs_(q: QD) -> QD:
    # the leading limb carries the sign of the whole expansion
    m = q.x0 < 0
    return QD(*[jnp.where(m, -l, l) for l in q.limbs()])


def where(c, a: QD, b: QD) -> QD:
    return QD(*[jnp.where(c, x, y) for x, y in zip(a.limbs(), b.limbs())])


def _vecsum_bottom_up(limbs: Sequence) -> list:
    """Bottom-up two_sum sweep: pushes the dominant mass into limb 0.

    Exact: the multiset of limbs keeps the same total value.
    """
    out = [None] * len(limbs)
    s = limbs[-1]
    for i in range(len(limbs) - 2, -1, -1):
        s, e = two_sum(limbs[i], s)
        out[i + 1] = e
    out[0] = s
    return out


def _compress_top_down(limbs: Sequence) -> list:
    """Top-down two_sum sweep: each error drops to the next slot. Exact."""
    acc = limbs[0]
    out = []
    for i in range(1, len(limbs)):
        acc, err = two_sum(acc, limbs[i])
        out.append(err)
    return [acc] + out


def renorm_list(terms: Sequence, k: int = 4, sweeps: int = 3) -> list:
    """Distill an arbitrary list of floats into a k-limb expansion.

    Alternating exact sweeps converge the list toward a non-overlapping
    expansion; after the final sweep the tail beyond k limbs is folded into
    limb k-1 with ordinary (rounding) adds.
    """
    limbs = list(terms)
    for _ in range(sweeps):
        limbs = _vecsum_bottom_up(limbs)
        limbs = _compress_top_down(limbs)
    head, tail = limbs[: k - 1], limbs[k - 1 :]
    last = tail[-1]
    for t in reversed(tail[:-1]):
        last = last + t
    head.append(last)
    # final canonicalizing pass
    head = _compress_top_down(_vecsum_bottom_up(head))
    return head


def add(a: QD, b: QD) -> QD:
    return QD(*renorm_list(a.limbs() + b.limbs(), k=4, sweeps=3))


def sub(a: QD, b: QD) -> QD:
    return add(a, neg(b))


def mul(a: QD, b: QD) -> QD:
    """Sloppy QD multiply: exact partial products through O(eps^3).

    Limb products for orders < 3 use the exact term decomposition
    (two_prod_terms) so the distilled result carries no two_prod slack;
    order-3 terms are plain (inexact) products, which is fine at O(eps^4).
    """
    al, bl = a.limbs(), b.limbs()
    terms = []
    for i in range(4):
        for j in range(4):
            o = i + j
            if o < 3:
                terms.extend(two_prod_terms(al[i], bl[j]))
            elif o == 3:
                terms.append(al[i] * bl[j])
    return QD(*renorm_list(terms, k=4, sweeps=3))


def mul_float(a: QD, b) -> QD:
    """QD * plain-float array.  Exact partial products through limb 2,
    distilled; cheaper than lifting ``b`` to QD for a full ``mul``."""
    b = jnp.asarray(b, a.dtype)
    terms = []
    for l in (a.x0, a.x1, a.x2):
        terms.extend(two_prod_terms(l, b))
    terms.append(a.x3 * b)
    return QD(*renorm_list(terms, k=4, sweeps=3))


def mul_pow2(a: QD, s) -> QD:
    """Exact scaling by a power of two."""
    return QD(*[l * s for l in a.limbs()])


def fma(acc: QD, a: QD, b: QD) -> QD:
    return add(acc, mul(a, b))


def div(a: QD, b: QD) -> QD:
    """Long-division QD / QD: five native-quotient correction rounds.

    Each round contributes ~53 bits of quotient (q_i = r.x0 / b.x0, then the
    remainder is updated exactly-ish via ``mul_float``), so five rounds
    overshoot the 212-bit format; the distilled q_i are the result.  Branch
    free, like everything in this module.
    """
    q_terms = []
    r = a
    for _ in range(5):
        qi = r.x0 / b.x0
        q_terms.append(qi)
        r = sub(r, mul_float(b, qi))
    return QD(*renorm_list(q_terms, k=4, sweeps=3))


def sqrt(a: QD) -> QD:
    """QD sqrt: DD seed (~106 bits) + one Heron step s <- (s + a/s)/2.

    Newton doubles the correct bits, so one step lands at ~212 — the format's
    capacity.  Zero is guarded (the seed's 1/sqrt would inf*0 -> nan).
    """
    from . import dd as _dd

    s0 = from_dd(_dd.sqrt(to_dd(a)))
    s = mul_pow2(add(s0, div(a, s0)), 0.5)
    zero = a.x0 == 0
    return QD(*[jnp.where(zero, jnp.zeros_like(l), l) for l in s.limbs()])


def sum_(a: QD, axis=None, keepdims=False) -> QD:
    """Compensated reduction along an axis by repeated halving (every
    partial stays a full QD expansion, mirroring dd.sum_)."""
    if axis is None:
        flat = QD(*[l.reshape(-1) for l in a.limbs()])
        return sum_(flat, axis=0, keepdims=keepdims)
    cur = QD(*[jnp.moveaxis(l, axis, 0) for l in a.limbs()])
    m = cur.x0.shape[0]
    while m > 1:
        half = m // 2
        even = QD(*[l[: 2 * half : 2] for l in cur.limbs()])
        odd = QD(*[l[1 : 2 * half : 2] for l in cur.limbs()])
        red = add(even, odd)
        if m % 2:
            tail = QD(*[
                jnp.concatenate([l[-1:], jnp.zeros_like(r[1:])], 0)
                for l, r in zip(cur.limbs(), red.limbs())
            ])
            red = add(red, tail)
        cur = red
        m = half
    out = QD(*[l[0] for l in cur.limbs()])
    if keepdims:
        out = QD(*[jnp.expand_dims(l, axis) for l in out.limbs()])
    return out


def dot(a: QD, b: QD) -> QD:
    """Inner product of two QD vectors with QD accumulation."""
    return sum_(mul(a, b), axis=0)
