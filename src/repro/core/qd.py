"""Quad-word expansion arithmetic (4 limbs) — past-binary128 precision.

binary128 carries a 113-bit mantissa; dd64 (dd.py) carries ~106.  When the
extra 7 bits matter, ``QD`` over f64 limbs (~212 bits) strictly dominates
binary128; over f32 limbs (~98 bits) it is the widest VPU-native format that
avoids f64 entirely (TPU Pallas/Mosaic has no f64 path).

We use CAMPARY-style *branch-free* renormalization (bottom-up two_sum sweeps
followed by top-down compression) rather than the branchy QD-library
renormalize: data-dependent branches do not vectorize in JAX.  The sweeps are
value-preserving (every step is an EFT); only the final truncation to 4 limbs
rounds.  Empirical accuracy is property-tested in tests/test_qd.py (observed
~2^-200 relative error for qd64 mul/add chains, comfortably past binary128's
2^-113).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp

from .efts import quick_two_sum, two_prod_terms, two_sum

__all__ = ["QD", "from_float", "from_dd", "to_float", "to_dd", "add", "sub", "mul", "neg", "fma", "renorm_list"]


class QD(NamedTuple):
    x0: jnp.ndarray
    x1: jnp.ndarray
    x2: jnp.ndarray
    x3: jnp.ndarray

    @property
    def dtype(self):
        return self.x0.dtype

    @property
    def shape(self):
        return self.x0.shape

    def limbs(self):
        return [self.x0, self.x1, self.x2, self.x3]


def from_float(x, dtype=None) -> QD:
    x = jnp.asarray(x, dtype=dtype)
    z = jnp.zeros_like(x)
    return QD(x, z, z, z)


def from_dd(x) -> QD:
    z = jnp.zeros_like(x.hi)
    return QD(x.hi, x.lo, z, z)


def to_float(q: QD):
    return ((q.x3 + q.x2) + q.x1) + q.x0


def to_dd(q: QD):
    from . import dd as _dd

    s, e = quick_two_sum(q.x0, q.x1)
    return _dd.DD(*quick_two_sum(s, e + (q.x2 + q.x3)))


def neg(q: QD) -> QD:
    return QD(-q.x0, -q.x1, -q.x2, -q.x3)


def _vecsum_bottom_up(limbs: Sequence) -> list:
    """Bottom-up two_sum sweep: pushes the dominant mass into limb 0.

    Exact: the multiset of limbs keeps the same total value.
    """
    out = [None] * len(limbs)
    s = limbs[-1]
    for i in range(len(limbs) - 2, -1, -1):
        s, e = two_sum(limbs[i], s)
        out[i + 1] = e
    out[0] = s
    return out


def _compress_top_down(limbs: Sequence) -> list:
    """Top-down two_sum sweep: each error drops to the next slot. Exact."""
    acc = limbs[0]
    out = []
    for i in range(1, len(limbs)):
        acc, err = two_sum(acc, limbs[i])
        out.append(err)
    return [acc] + out


def renorm_list(terms: Sequence, k: int = 4, sweeps: int = 3) -> list:
    """Distill an arbitrary list of floats into a k-limb expansion.

    Alternating exact sweeps converge the list toward a non-overlapping
    expansion; after the final sweep the tail beyond k limbs is folded into
    limb k-1 with ordinary (rounding) adds.
    """
    limbs = list(terms)
    for _ in range(sweeps):
        limbs = _vecsum_bottom_up(limbs)
        limbs = _compress_top_down(limbs)
    head, tail = limbs[: k - 1], limbs[k - 1 :]
    last = tail[-1]
    for t in reversed(tail[:-1]):
        last = last + t
    head.append(last)
    # final canonicalizing pass
    head = _compress_top_down(_vecsum_bottom_up(head))
    return head


def add(a: QD, b: QD) -> QD:
    return QD(*renorm_list(a.limbs() + b.limbs(), k=4, sweeps=3))


def sub(a: QD, b: QD) -> QD:
    return add(a, neg(b))


def mul(a: QD, b: QD) -> QD:
    """Sloppy QD multiply: exact partial products through O(eps^3).

    Limb products for orders < 3 use the exact term decomposition
    (two_prod_terms) so the distilled result carries no two_prod slack;
    order-3 terms are plain (inexact) products, which is fine at O(eps^4).
    """
    al, bl = a.limbs(), b.limbs()
    terms = []
    for i in range(4):
        for j in range(4):
            o = i + j
            if o < 3:
                terms.extend(two_prod_terms(al[i], bl[j]))
            elif o == 3:
                terms.append(al[i] * bl[j])
    return QD(*renorm_list(terms, k=4, sweeps=3))


def fma(acc: QD, a: QD, b: QD) -> QD:
    return add(acc, mul(a, b))
