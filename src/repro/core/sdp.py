"""Semidefinite programming via the primal-dual interior point method.

Reproduces the paper's §V-B: an SDPA-style Mehrotra predictor-corrector
PDIPM (HRVW/KSH search direction) whose linear algebra is *precision
parameterized* — ``double`` runs on plain f64, ``binary128`` routes every
GEMM / Cholesky / Schur solve through the DD engine (the paper's accelerated
Rgemm + MPLAPACK stack), and ``binary128+`` routes the same pipeline through
the quad-word (4-limb, ~212-bit) tier for instances where the paper's
"binary128 **or higher**" clause bites.  The headline claim this reproduces
is Table V: in double precision the relative gap stalls near 1e-8 because X
and Z go singular at the optimum [Nakata 2010]; in binary128-class
arithmetic the same algorithm pushes gaps to ~1e-25 — and where a
degenerate Schur system floors the dd tier itself (observed 1.3e-24 at
cond(B)~1e10), the qd tier keeps descending (observed 8.9e-28; see
tests/test_sdp.py).  Crucially the m x m Schur system is
also solved in extended precision — near the optimum cond(B) ~ 1/mu^2, so a
double-precision Schur solve caps the achievable gap; this is exactly why
SDPA-GMP/-DD route *all* BLAS through the high-precision backend.

Standard form:
    primal:  min  C . X      s.t.  A_i . X = b_i,  X psd
    dual:    max  b^T y      s.t.  Z = C - sum_i y_i A_i psd

Schur complement system (KSH):  B dy = rhs,
    B_ij  = tr(A_i X A_j Z^-1)          (symmetric positive definite)
    rhs_i = r_p_i - A_i.(d Z^-1) + A_i.(X R_d Z^-1)
    d     = sigma*mu*I - X Z [- dX dZ for the corrector]
    dZ    = R_d - sum_j dy_j A_j
    dX    = (d - X dZ) Z^-1, symmetrized.

Step lengths use Cholesky-test backtracking (the practical alternative to
SDPA's Lanczos bound).

GEMM backend note: the default here is the per-element DD backend ("xla"),
NOT the Ozaki path.  Ozaki slices on a per-row fixed-point grid, so its
error is *absolute* w.r.t. each row's max — near the IPM optimum the
batched solves mix O(1/mu) and O(1) blocks in one row and the small blocks
lose exactly the bits the method needs (observed: the gap floors at ~1e-13
instead of ~1e-25).  Per-element DD error is *relative*, which is what an
interior-point method requires.  This scaling caveat is inherent to the
Ozaki scheme and documented in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np

from repro.gemm import matmul as dd_matmul

from . import dd, mp, qd, td
from .blas import transpose
from .linalg import cholesky_solve, rpotrf

__all__ = ["SDPProblem", "SDPResult", "solve_sdp", "random_sdp", "theta_problem"]


# --------------------------------------------------------------------------
# precision backends (matrices: (n,n); stacks: (m,n,n); vectors: (m,))
# --------------------------------------------------------------------------


class _F64Ops:
    name = "double"

    def wrap(self, a_np):
        return jnp.asarray(a_np, jnp.float64)

    def eye(self, n, scale=1.0):
        return jnp.eye(n, dtype=jnp.float64) * scale

    def matmul(self, a, b):
        return a @ b

    add = staticmethod(lambda a, b: a + b)
    sub = staticmethod(lambda a, b: a - b)

    def smul(self, s, a):
        s = s if not isinstance(s, (float, int)) else jnp.float64(s)
        return s * a

    def trace_dot(self, a, b):
        return jnp.sum(a * b)

    def stack_trace(self, stack, mat):
        """(m,) vector of tr(A_i mat) = sum(A_i * mat^T)."""
        return jnp.einsum("ikl,lk->i", stack, mat)

    def combine(self, vec, stack):
        """sum_i vec_i A_i."""
        return jnp.einsum("i,ikl->kl", vec, stack)

    def pairwise_trace(self, stack, vstack):
        """B_ij = tr(A_i V_j) = sum_kl A_i[kl] V_j[lk]."""
        return jnp.einsum("ikl,jlk->ij", stack, vstack)

    def chol(self, a):
        return jnp.linalg.cholesky(a)

    def chol_solve(self, l, b):
        y = jsl.solve_triangular(l, b, lower=True)
        return jsl.solve_triangular(l.T, y, lower=False)

    def solve_spd(self, bmat, rhs):
        l = jnp.linalg.cholesky(bmat)
        y = jsl.solve_triangular(l, rhs[:, None], lower=True)
        return jsl.solve_triangular(l.T, y, lower=False)[:, 0]

    def t(self, a):
        return a.T if a.ndim == 2 else jnp.swapaxes(a, -1, -2)

    def to_float(self, a) -> float:
        return float(np.asarray(a))

    def to_np(self, a):
        return np.asarray(a, np.float64)

    def has_nan(self, a) -> bool:
        return bool(jnp.isnan(a).any())

    def scalar(self, x: float):
        return jnp.float64(x)

    def max_abs(self, a) -> float:
        return float(jnp.abs(a).max())


# jitted multi-limb kernels shared by the dd/qd ops backends: one PDIPM
# iteration otherwise dispatches thousands of tiny eager jnp ops (a qd.add
# alone is ~300), which dominates wall time at SDP-test sizes.  Shapes are
# stable across iterations, so each (function, shape, limb-count) traces
# once.  mp dispatches on the operand type inside the trace.
_ml_add = jax.jit(mp.add)
_ml_sub = jax.jit(mp.sub)
_ml_smul_ml = jax.jit(lambda s, a: mp.mul(mp.broadcast_to(s, a.shape), a))
_ml_smul_f = jax.jit(mp.mul_float)
_ml_trace_dot = jax.jit(lambda a, b: mp.sum_(mp.mul(a, b)))


@jax.jit
def _ml_stack_trace(stack, mat):
    m = stack.shape[0]
    tm = mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), mat)
    prod = mp.mul(stack, mp.map_limbs(lambda l: l[None], tm))
    return mp.sum_(prod.reshape(m, -1), axis=1)


@jax.jit
def _ml_combine(vec, stack):
    w = mp.map_limbs(lambda l: l[:, None, None], vec)
    return mp.sum_(mp.mul(w, stack), axis=0)


@jax.jit
def _ml_pairwise_trace(stack, vstack):
    m = stack.shape[0]
    a = mp.map_limbs(lambda l: l[:, None], stack)               # (m,1,n,n)
    vt = mp.map_limbs(lambda l: jnp.swapaxes(l, -1, -2), vstack)
    v = mp.map_limbs(lambda l: l[None, :], vt)                  # (1,m,n,n)
    prod = mp.mul(a, v)
    return mp.sum_(prod.reshape(m, m, -1), axis=2)


class _MLOps:
    """Shared multi-limb ops backend; subclasses fix the tier module."""

    mod = dd  # overridden
    # Schur solves factor at this rung and refine at the tier's own
    # precision (repro.solve rgesv/rposv): dd is the cheapest rung whose
    # factorization survives mid-path Schur conditioning, and the
    # escalation ladder re-factors at the tier itself when cond(B) ~
    # 1/mu^2 outgrows the rung near the optimum
    schur_factor_tier = "dd"

    def __init__(self, plan_overrides: dict | None = None):
        # planner overrides, not a hand-threaded backend string: the engine
        # plans each call from shape/platform/operand tier and these pins
        # (default xla — see the module docstring's Ozaki scaling caveat).
        # An explicit {} means "no pins": full auto planning.
        self.plan_overrides = dict(plan_overrides) if plan_overrides is not None \
            else {"backend": "xla"}
        # aggregate refinement telemetry across every Schur solve of one
        # PDIPM run (surfaced as SDPResult.schur_stats)
        self.schur_stats = {"solves": 0, "iterations": 0, "escalations": 0,
                            "factorizations": {}}

    def wrap(self, a_np):
        return self.mod.from_float(jnp.asarray(a_np, jnp.float64))

    def eye(self, n, scale=1.0):
        return self.mod.from_float(jnp.eye(n, dtype=jnp.float64) * scale)

    def matmul(self, a, b):
        # (..., n, n) leading batch dims route through the engine's vmapped
        # batched path — the per-constraint stacks run as one call
        return dd_matmul(a, b, **self.plan_overrides)

    add = staticmethod(_ml_add)
    sub = staticmethod(_ml_sub)

    def smul(self, s, a):
        if isinstance(s, (dd.DD, td.TD, qd.QD)):
            return _ml_smul_ml(mp.promote(s, mp.precision_of(a)), a)
        return _ml_smul_f(a, jnp.float64(s))

    trace_dot = staticmethod(_ml_trace_dot)
    stack_trace = staticmethod(_ml_stack_trace)
    combine = staticmethod(_ml_combine)
    pairwise_trace = staticmethod(_ml_pairwise_trace)

    def chol(self, a):
        return rpotrf(a)

    def chol_solve(self, l, b):
        return cholesky_solve(l, b)

    def solve_spd(self, bmat, rhs):
        # the Schur system B dy = rhs through the tiered refinement solver:
        # factor once at the cheap rung, refine residuals at this tier's
        # precision through the engine, escalate on stagnation.  For the
        # qd tier this is the paper's application story — binary128+
        # accuracy at (mostly) binary128 factorization cost.
        from repro.solve import rposv

        dy, info = rposv(bmat, rhs, factor_tier=self.schur_factor_tier,
                         target_tier=mp.precision_of(bmat), max_iters=12,
                         **self.plan_overrides)
        st = self.schur_stats
        st["solves"] += 1
        st["iterations"] += info.iterations
        st["escalations"] += len(info.escalations)
        for tier, cnt in info.factorizations.items():
            st["factorizations"][tier] = \
                st["factorizations"].get(tier, 0) + cnt
        last_measured = info.backward_errors[-1] \
            if info.backward_errors else float("nan")
        topped_out = bool(info.factor_tiers) and \
            info.factor_tiers[-1] == info.target_tier
        if not info.converged and topped_out \
                and not math.isfinite(last_measured) \
                and not info.final_backward_error < 0.5:
            # the ladder topped out with a broken factorization (NaN
            # residual at the target rung itself) AND no meaningfully
            # refined direction exists (the best finite iterate is the
            # ~trivial one, berr ~ 1): preserve the direct solve's
            # failure signal — the PDIPM loop breaks on NaN at its
            # precision floor rather than iterating on a frozen
            # direction.  A NaN on a lower rung, or a target-rung
            # divergence AFTER a usable iterate was found, is not
            # terminal: _refine already fell back to its best finite
            # iterate and that direction is returned
            return mp.map_limbs(lambda x: jnp.full_like(x, jnp.nan), dy)
        return dy

    def t(self, a):
        return transpose(a)

    def to_float(self, a) -> float:
        return float(np.asarray(mp.to_float(a)))

    def to_np(self, a):
        return np.asarray(mp.to_float(a), np.float64)

    def has_nan(self, a) -> bool:
        return bool(np.any([jnp.isnan(l).any() for l in mp.limbs(a)]))

    def scalar(self, x: float):
        return self.mod.from_float(jnp.float64(x))

    def max_abs(self, a) -> float:
        return float(np.abs(np.asarray(mp.to_float(a))).max())


class _DDOps(_MLOps):
    """binary128 backend: double-word (~106-bit) limbs, the paper's tier."""

    name = "binary128"
    mod = dd


class _TDOps(_MLOps):
    """binary192 backend: triple-word (~159-bit) limbs — the middle rung
    between the paper's binary128 tier and binary128+, for instances
    where dd floors the gap but a full qd run overpays."""

    name = "binary192"
    mod = td


class _QDOps(_MLOps):
    """binary128+ backend: quad-word (~212-bit) limbs for instances where
    the dd tier's Schur-solve noise floors the gap.  The engine infers
    ``precision="qd"`` from the operand type; the Ozaki caveat does not
    arise (the qd tier has no ozaki path), but backend="xla" is still
    pinned so plans skip the per-call env/default resolution."""

    name = "binary128+"
    mod = qd


def _ops(precision: str, gemm_overrides: dict | None = None):
    if precision in ("double", "f64"):
        return _F64Ops()
    if precision in ("binary128", "dd", "dd64"):
        return _DDOps(gemm_overrides)
    if precision in ("binary192", "td", "td64"):
        return _TDOps(gemm_overrides)
    if precision in ("binary128+", "qd", "qd64"):
        return _QDOps(gemm_overrides)
    raise ValueError(f"unknown precision {precision!r}")


# --------------------------------------------------------------------------
# problems
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SDPProblem:
    """min C.X s.t. A_i.X = b_i, X psd.  All numpy f64 (exact input data)."""

    c: np.ndarray            # (n, n) symmetric
    a: List[np.ndarray]      # m matrices (n, n) symmetric
    b: np.ndarray            # (m,)
    opt: float | None = None  # known optimal value, if any
    name: str = "sdp"

    @property
    def n(self) -> int:
        return self.c.shape[0]

    @property
    def m(self) -> int:
        return len(self.a)


@dataclasses.dataclass
class SDPResult:
    x: np.ndarray
    y: np.ndarray
    z: np.ndarray
    iterations: int
    relative_gap: float
    p_feas_err: float
    d_feas_err: float
    primal_obj: float
    dual_obj: float
    converged: bool
    history: list
    # aggregate refinement telemetry of the Schur solves (multi-limb
    # precisions only): solves / refine iterations / escalations and the
    # per-rung factorization counts — the "factored cheap, refined at
    # target" cost story in numbers
    schur_stats: dict | None = None


def random_sdp(n: int, m: int, seed: int = 0, rank: int | None = None,
               degeneracy: float = 0.0) -> SDPProblem:
    """Random SDP with a KNOWN strictly-complementary optimal pair.

    X* = Q diag(lam, 0) Q^T (rank r), Z* = Q diag(0, omega) Q^T, X* Z* = 0;
    b_i = A_i . X*, C = Z* + sum_i y*_i A_i  ==> opt = C . X* = b^T y*.

    ``degeneracy`` > 0 makes A_2 nearly parallel to A_1 (A_2 <- A_1 + eps*G):
    the Schur complement B_ij = tr(A_i X A_j Z^-1) then carries cond(B) ~
    1/degeneracy^2, which floors the achievable gap of a tier at roughly
    eps_tier * cond(B) — the paper's §V-B motivation ("binary128 or higher")
    as a dial.  b/C are computed AFTER the perturbation, so the optimal
    certificate stays exact.
    """
    rng = np.random.default_rng(seed)
    r = rank if rank is not None else max(1, n // 2)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = rng.uniform(0.5, 2.0, size=r)
    omega = rng.uniform(0.5, 2.0, size=n - r)
    x_star = q[:, :r] @ np.diag(lam) @ q[:, :r].T
    z_star = q[:, r:] @ np.diag(omega) @ q[:, r:].T
    a_mats = []
    for _ in range(m):
        g = rng.standard_normal((n, n))
        a_mats.append((g + g.T) / 2)
    if degeneracy and m >= 2:
        a_mats[1] = a_mats[0] + degeneracy * a_mats[1]
    y_star = rng.standard_normal(m)
    b = np.array([np.sum(ai * x_star) for ai in a_mats])
    c = z_star + sum(yi * ai for yi, ai in zip(y_star, a_mats))
    opt = float(np.sum(c * x_star))
    return SDPProblem(c=c, a=a_mats, b=b, opt=opt, name=f"rand{n}x{m}")


def theta_problem(n_vertices: int, edge_prob: float = 0.4, seed: int = 0) -> SDPProblem:
    """Lovasz theta SDP (the SDPLIB 'theta*' family): max J.X s.t. tr X = 1,

    X_ij = 0 on edges, X psd.  Returned in min form (C = -J).
    """
    rng = np.random.default_rng(seed)
    n = n_vertices
    edges = [(i, j) for i in range(n) for j in range(i + 1, n)
             if rng.random() < edge_prob]
    a_mats = [np.eye(n)]
    b = [1.0]
    for (i, j) in edges:
        e = np.zeros((n, n))
        e[i, j] = e[j, i] = 0.5
        a_mats.append(e)
        b.append(0.0)
    c = -np.ones((n, n))
    return SDPProblem(c=c, a=a_mats, b=np.array(b), opt=None,
                      name=f"theta{n}")


# --------------------------------------------------------------------------
# solver
# --------------------------------------------------------------------------


def _step_length(ops, mat, dmat, gamma: float) -> float:
    """gamma * (largest alpha <= 1 with mat + alpha*dmat psd).

    The gamma fraction keeps iterates strictly interior — taking the full
    boundary step makes X/Z indefinite one iteration later (observed: mu
    goes negative and the iteration NaNs).
    """
    alpha = 1.0
    for _ in range(80):
        trial = ops.add(mat, ops.smul(alpha, dmat))
        l = ops.chol(trial)
        if not ops.has_nan(l):
            return gamma * alpha
        alpha *= 0.7
    return 1e-8


def solve_sdp(prob: SDPProblem, *, precision: str = "binary128",
              gemm_overrides: dict | None = None, max_iters: int = 120,
              tol_gap: float | None = None, gamma: float = 0.9,
              schur_factor_tier: str | None = None,
              verbose: bool = False) -> SDPResult:
    """SDPA-style Mehrotra predictor-corrector PDIPM (precision-generic).

    ``precision`` picks the arithmetic ladder rung: ``"double"`` (f64),
    ``"binary128"`` (dd, ~106 bits), ``"binary192"`` (td, ~159 bits), or
    ``"binary128+"`` (qd, ~212 bits).
    ``gemm_overrides`` feeds the GEMM engine's planner for every extended-
    precision product (default pins backend="xla"; see the Ozaki caveat
    above — the engine infers the limb count from the operand type).
    ``schur_factor_tier`` overrides the rung the Schur system is
    *factored* at (default dd): e.g. ``precision="binary128+"`` with
    ``schur_factor_tier="td"`` starts the refinement ladder at td, paying
    ~td factorization cost for the late-path iterations where dd's
    factorization has already outlived its conditioning budget.
    Passing ``mesh=`` (plus optional ``shard_axis``/``shard_axis_n``)
    distributes every Schur-stack GEMM — including the vmap-batched
    per-constraint ``X @ (A_j Z^-1)`` stack — over a 2-D device mesh via
    the engine's SUMMA path (DESIGN.md §11); ``comm=`` picks the panel
    schedule (default ppermute ring) and ``k_stream=`` adds host-side
    out-of-core K streaming for Schur stacks too deep to hold per-device.
    """
    ops = _ops(precision, gemm_overrides)
    if schur_factor_tier is not None:
        if not hasattr(ops, "schur_factor_tier"):
            raise ValueError(
                "schur_factor_tier only applies to the extended-precision "
                "backends (binary128/binary192/binary128+)")
        ops.schur_factor_tier = schur_factor_tier
    if tol_gap is None:
        tol_gap = {"binary128+": 1e-40, "binary192": 1e-32,
                   "binary128": 1e-25}.get(ops.name, 1e-12)
    n, m = prob.n, prob.m

    c = ops.wrap(prob.c)
    astack = ops.wrap(np.stack(prob.a))          # (m, n, n)
    b_np = prob.b.astype(np.float64)
    b_vec = ops.wrap(b_np)                       # (m,)

    scale = max(1.0, float(np.abs(prob.c).max()), float(np.abs(prob.b).max()))
    x = ops.eye(n, 10.0 * scale)
    z = ops.eye(n, 10.0 * scale)
    y = ops.wrap(np.zeros(m))

    history = []
    gap = pfeas = dfeas = np.inf
    pobj = dobj = 0.0
    best = None  # (gap, pfeas, dfeas, pobj, dobj, x, y, z, it)
    it = 0
    for it in range(1, max_iters + 1):
        r_d = ops.sub(ops.sub(c, ops.combine(y, astack)), z)   # C - sum yA - Z
        r_p = ops.sub(b_vec, ops.stack_trace(astack, x))       # (m,)

        mu_f = ops.to_float(ops.trace_dot(x, z)) / n
        pobj_b = ops.trace_dot(c, x)
        dobj_b = ops.trace_dot(b_vec, y) if hasattr(y, "shape") else None
        pobj = ops.to_float(pobj_b)
        dobj = ops.to_float(dobj_b)
        # gap difference computed in backend precision (an f64 subtraction
        # of the objectives floors the METRIC at ~1e-16 relative)
        gap_abs = abs(ops.to_float(ops.sub(pobj_b, dobj_b)))
        gap = gap_abs / max(1.0, (abs(pobj) + abs(dobj)) / 2)
        pfeas = ops.max_abs(r_p)
        dfeas = ops.max_abs(r_d)
        history.append((it, gap, pfeas, dfeas, mu_f))
        if verbose:
            print(f"  it {it:3d}  gap {gap:9.2e}  pfeas {pfeas:9.2e}"
                  f"  dfeas {dfeas:9.2e}  mu {mu_f:9.2e}")
        if best is None or gap < best[0]:
            best = (gap, pfeas, dfeas, pobj, dobj, x, y, z, it)
        if gap < tol_gap and pfeas < 1e-3 * np.sqrt(tol_gap) * scale \
                and dfeas < 1e-3 * np.sqrt(tol_gap) * scale:
            break
        if not np.isfinite(mu_f) or mu_f <= 0 or not np.isfinite(gap):
            break  # numerical floor of the precision backend
        if best is not None and gap > 1e4 * best[0] and best[0] < 1e-6:
            break  # diverging past the precision floor: stop at best iterate

        # factorizations shared by predictor + corrector
        lz = ops.chol(z)
        xz = ops.matmul(x, z)
        # V_j = X A_j Z^-1 = X (Z^-1 A_j)^T  -> B_ij = tr(A_i V_j)
        u = ops.chol_solve(lz, _hstack(ops, astack, n, m))     # blocks Z^-1 A_j
        s_stack = ops.t(_unstack(ops, u, n, m))                # blocks A_j Z^-1
        # one batched GEMM over the constraint stack: X @ (A_j Z^-1) for all
        # j in a single engine call (the engine vmaps the planned kernel)
        vstack = ops.matmul(x, s_stack)                        # (m, n, n)
        bmat = ops.pairwise_trace(astack, vstack)
        bmat = ops.smul(0.5, ops.add(bmat, ops.t(bmat)))

        x_rd = ops.matmul(x, r_d)
        xrd_zinv = ops.t(ops.chol_solve(lz, ops.t(x_rd)))      # X R_d Z^-1

        def solve_direction(d):
            d_zinv = ops.t(ops.chol_solve(lz, ops.t(d)))       # d Z^-1
            rhs = ops.add(
                ops.sub(r_p, ops.stack_trace(astack, d_zinv)),
                ops.stack_trace(astack, xrd_zinv),
            )
            dy = ops.solve_spd(bmat, rhs)
            dz = ops.sub(r_d, ops.combine(dy, astack))
            rhs_x = ops.sub(d, ops.matmul(x, dz))
            dx = ops.t(ops.chol_solve(lz, ops.t(rhs_x)))       # (d - X dZ) Z^-1
            dx = ops.smul(0.5, ops.add(dx, ops.t(dx)))
            return dy, dx, dz

        # predictor (affine scaling): d = -X Z
        # adaptive gamma: approach 1 near the optimum (fixed 0.9 caps the
        # per-iteration mu reduction and stalls the endgame)
        g_it = max(gamma, 1.0 - 1e-2 * max(mu_f, 1e-30) ** 0.25) if mu_f < 1e-4 else gamma
        g_it = min(g_it, 1.0 - 1e-12)
        dy_a, dx_a, dz_a = solve_direction(ops.smul(-1.0, xz))
        ap = _step_length(ops, x, dx_a, g_it)
        ad = _step_length(ops, z, dz_a, g_it)
        x_trial = ops.add(x, ops.smul(ap, dx_a))
        z_trial = ops.add(z, ops.smul(ad, dz_a))
        mu_aff = ops.to_float(ops.trace_dot(x_trial, z_trial)) / n
        ratio = min(1.0, max(mu_aff / max(mu_f, 1e-307), 0.0))
        sigma = max(ratio ** 3, 1e-12)

        # corrector: d = sigma*mu*I - X Z - dX_a dZ_a
        d_cor = ops.sub(ops.sub(ops.eye(n, sigma * mu_f), xz),
                        ops.matmul(dx_a, dz_a))
        dy, dx, dz = solve_direction(d_cor)
        ap = _step_length(ops, x, dx, g_it)
        ad = _step_length(ops, z, dz, g_it)
        # EQUAL primal/dual steps: unequal steps let the X/Z eigen-pairings
        # drift off the central path (lambda_min(Z) overshoots mu), after
        # which dX ~ (d - X dZ) Z^-1 blows up by 1/lambda_min(Z) — observed
        # |dX| growing 33 -> 1e8 over 5 iterations with perfectly-solved
        # Newton systems.  Locking alpha_p = alpha_d keeps tr(XZ) pairings
        # aligned and lets DD reach its genuine precision floor.
        a_eq = min(ap, ad)

        x = ops.add(x, ops.smul(a_eq, dx))
        y = ops.add(y, ops.smul(a_eq, dy))
        z = ops.add(z, ops.smul(a_eq, dz))

    # NaN-robust: fall back to the best iterate unless the final one is
    # strictly better (NaN comparisons are False, so `best[0] < gap` alone
    # would keep a NaN final state)
    if best is not None and not (gap <= best[0]):
        gap, pfeas, dfeas, pobj, dobj, x, y, z, _ = best
    return SDPResult(
        x=ops.to_np(x), y=ops.to_np(y), z=ops.to_np(z), iterations=it,
        relative_gap=float(gap), p_feas_err=float(pfeas),
        d_feas_err=float(dfeas), primal_obj=pobj, dual_obj=dobj,
        converged=bool(gap < tol_gap), history=history,
        schur_stats=getattr(ops, "schur_stats", None),
    )


def _hstack(ops, astack, n: int, m: int):
    """(m,n,n) -> (n, m*n) horizontal concat of the A_j."""
    f = lambda x: jnp.transpose(x, (1, 0, 2)).reshape(n, m * n)  # noqa: E731
    if isinstance(astack, (dd.DD, td.TD, qd.QD)):
        return mp.map_limbs(f, astack)
    return f(astack)


def _unstack(ops, v, n: int, m: int):
    """(n, m*n) -> (m, n, n)."""
    f = lambda x: jnp.transpose(x.reshape(n, m, n), (1, 0, 2))  # noqa: E731
    if isinstance(v, (dd.DD, td.TD, qd.QD)):
        return mp.map_limbs(f, v)
    return f(v)
