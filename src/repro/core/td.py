"""Triple-word expansion arithmetic (3 limbs) — the ~159-bit middle rung.

binary128 carries a 113-bit mantissa; dd64 (dd.py) carries ~106 and qd64
(qd.py) ~212.  The gap between them is a 2x-limb jump (~4x flop cost) that
the refinement ladder previously had to take whole even when ~160 bits
would converge.  ``TD`` over f64 limbs (~159 bits) is that missing rung —
and, deliberately, the *proof* rung of the count-generic refactor: every
function here is a thin binding of the count-parametric kernel family in
``core/mp.py`` at k == 3, with no triple-word-specific algorithm anywhere.
Adding the next rung is the same dozen lines at a different count.

Accuracy is property-tested in tests/test_td.py (observed ~2^-150-class
relative error for td64 mul/add chains, comfortably past binary128's
2^-113) and gated on the exact-rational Hilbert GEMM
(tests/test_accuracy_gate.py, td <= 2^-150).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import mp as _mp
from .mp import renorm_list  # re-exported, mirroring qd

__all__ = ["TD", "from_float", "from_dd", "to_float", "to_dd", "zeros",
           "add", "sub", "mul", "mul_float", "mul_pow2", "neg", "abs_",
           "fma", "div", "sqrt", "where", "sum_", "dot", "eps",
           "renorm_list"]


class TD(NamedTuple):
    x0: jnp.ndarray
    x1: jnp.ndarray
    x2: jnp.ndarray

    @property
    def dtype(self):
        return self.x0.dtype

    @property
    def shape(self):
        return self.x0.shape

    def limbs(self):
        return [self.x0, self.x1, self.x2]

    def __getitem__(self, idx):
        return TD(self.x0[idx], self.x1[idx], self.x2[idx])

    def reshape(self, *shape):
        return TD(*[l.reshape(*shape) for l in self.limbs()])


def eps(dtype) -> float:
    """Unit roundoff of the TD format with the given limb dtype."""
    return _mp.eps_for(3, dtype)


def from_float(x, dtype=None) -> TD:
    x = jnp.asarray(x, dtype=dtype)
    z = jnp.zeros_like(x)
    return TD(x, z, z)


def from_dd(x) -> TD:
    z = jnp.zeros_like(x.hi)
    return TD(x.hi, x.lo, z)


def to_float(t: TD):
    return (t.x2 + t.x1) + t.x0


def to_dd(t: TD):
    from . import dd as _dd

    return _dd.DD(*_mp.to_dd_limbs(t.limbs()))


def zeros(shape, dtype=jnp.float64) -> TD:
    z = jnp.zeros(shape, dtype=dtype)
    return TD(z, z, z)


def neg(t: TD) -> TD:
    return TD(-t.x0, -t.x1, -t.x2)


def abs_(t: TD) -> TD:
    # the leading limb carries the sign of the whole expansion
    m = t.x0 < 0
    return TD(*[jnp.where(m, -l, l) for l in t.limbs()])


def where(c, a: TD, b: TD) -> TD:
    return TD(*[jnp.where(c, x, y) for x, y in zip(a.limbs(), b.limbs())])


def add(a: TD, b: TD) -> TD:
    return TD(*_mp.add_limbs(a.limbs(), b.limbs()))


def sub(a: TD, b: TD) -> TD:
    return add(a, neg(b))


def mul(a: TD, b: TD) -> TD:
    return TD(*_mp.mul_limbs(a.limbs(), b.limbs()))


def mul_float(a: TD, b) -> TD:
    return TD(*_mp.mul_float_limbs(a.limbs(), b))


def mul_pow2(a: TD, s) -> TD:
    """Exact scaling by a power of two."""
    return TD(*_mp.mul_pow2_limbs(a.limbs(), s))


def fma(acc: TD, a: TD, b: TD) -> TD:
    return add(acc, mul(a, b))


def div(a: TD, b: TD) -> TD:
    return TD(*_mp.div_limbs(a.limbs(), b.limbs()))


def sqrt(a: TD) -> TD:
    return TD(*_mp.sqrt_limbs(a.limbs()))


def sum_(a: TD, axis=None, keepdims=False) -> TD:
    return TD(*_mp.sum_limbs(a.limbs(), axis=axis, keepdims=keepdims))


def dot(a: TD, b: TD) -> TD:
    """Inner product of two TD vectors with TD accumulation."""
    return sum_(mul(a, b), axis=0)
