"""Data pipeline: deterministic synthetic token streams, sharded + prefetched."""

from .pipeline import DataConfig, TokenStream, make_batch_iterator  # noqa: F401
