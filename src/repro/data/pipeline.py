"""Deterministic synthetic LM data pipeline.

Production properties kept even though the tokens are synthetic:

  * stateless addressing — batch `i` is a pure function of (seed, step), so
    the iterator state IS the step counter: restart-safe by construction,
    no data-order drift across checkpoint/restore (test_checkpoint.py).
  * host-sharded — each data-parallel host materializes only its slice
    (``shard``/``num_shards``), matching multi-host TPU input pipelines.
  * learnable structure — tokens follow a k-gram Markov chain derived from
    the seed, so small-model training loss demonstrably decreases (the
    end-to-end example trains on it).
  * double-buffered prefetch thread with a bounded queue.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "TokenStream", "make_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    order: int = 2          # markov order of the synthetic language


class TokenStream:
    """Deterministic k-gram-Markov token source, stateless per step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 4096)  # transition table cap
        self._v = v
        # sparse-ish row-stochastic transition logits: each context prefers
        # a handful of successors -> learnable structure
        self._succ = rng.integers(0, v, size=(v, 8))
        self._succ_p = rng.dirichlet(np.ones(8) * 0.5, size=v)

    @property
    def local_batch(self) -> int:
        assert self.cfg.global_batch % self.cfg.num_shards == 0
        return self.cfg.global_batch // self.cfg.num_shards

    def batch_at(self, step: int) -> dict:
        """The shard-local batch for a given step (pure function)."""
        cfg = self.cfg
        lb = self.local_batch
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard]))
        toks = np.empty((lb, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._v, lb)
        for t in range(cfg.seq_len):
            cur = toks[:, t]
            choice = rng.random(lb)
            cum = np.cumsum(self._succ_p[cur], axis=1)
            idx = (choice[:, None] < cum).argmax(axis=1)
            toks[:, t + 1] = self._succ[cur, idx]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }


def make_batch_iterator(cfg: DataConfig, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[dict]:
    """Prefetching iterator; resume by passing the checkpointed step."""
    stream = TokenStream(cfg)
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, stream.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=producer, daemon=True)
    th.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            step, batch = q.get()
            batch["step"] = step
            return batch

        def close(self):
            stop.set()

    return _Iter()
