"""Unified GEMM execution engine: plan -> (auto)tune -> dispatch.

The paper streams every GEMM-shaped workload through one fixed FPGA design;
this package is that discipline in software.  Every extended-precision
matmul in the repo funnels through:

    plan  = make_plan(m, k, n, dtype=..., backend=..., mesh=...)
    c     = execute(plan, a, b)         # or matmul(a, b, ...) to do both

``make_plan`` picks the precision tier (dd = 2-limb binary128 class |
qd = 4-limb binary128+), the backend (pallas | ozaki | ozaki-pallas |
xla | ref), block shapes (tuned cache > heuristics), limb/slice dtypes and
solved slice parameters per platform, and the batch / sharding strategy.
``execute``/``matmul`` also carry the optional Rgemm alpha/beta epilogue
(fused into the ozaki-pallas kernel drain, post-step elsewhere).
``autotune`` sweeps block shapes — × n_slices for the slicing kernel —
with the paper's resource models and persists winners on disk keyed by
(schema, shape-bucket, dtype, limb count, platform), so each precision
tier tunes its own tiles.  See DESIGN.md §4 (flow), §8 (precision
ladder), and §9 (MXU-resident Ozaki slicing).
"""

from .plan import BACKENDS, FALLBACK_CHAINS, PRECISIONS, GemmPlan, \
    fallback_chain, make_plan, replan_precision, resolve_backend
from .engine import execute, matmul
from .autotune import autotune, candidate_blocks, vmem_bytes
from .cache import PlanCache, batch_bucket, cache_key, clear_quarantine, \
    default_cache, quarantine, quarantined, set_default_cache, shape_bucket
from .guard import CHECKS, resolve_check
# the hazard taxonomy lives in runtime.faults (it spans GEMM and solver
# layers); re-exported here because GEMM callers meet it first
from repro.runtime.faults import BackendExecutionError, \
    BackendFailoverWarning, NumericalHazardError, SliceOverflowError

__all__ = [
    "BACKENDS", "FALLBACK_CHAINS", "PRECISIONS", "GemmPlan", "make_plan",
    "replan_precision", "resolve_backend", "fallback_chain",
    "execute", "matmul",
    "autotune", "candidate_blocks", "vmem_bytes",
    "PlanCache", "batch_bucket", "cache_key", "default_cache",
    "set_default_cache", "shape_bucket",
    "CHECKS", "resolve_check",
    "quarantine", "quarantined", "clear_quarantine",
    "NumericalHazardError", "SliceOverflowError", "BackendExecutionError",
    "BackendFailoverWarning",
]
