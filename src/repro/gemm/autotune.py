"""Block-shape autotuner — the runtime analogue of the paper's M_Tile sweep.

The paper sweeps the per-PE memory tile (M_Tile) and PE-array shape at
synthesis time (Fig. 3, Tables II/III) and ships the best configuration.
Here the same sweep runs once per (shape-bucket, dtype, platform) at
runtime: candidate (bm, bn, bk) tiles are filtered by the VMEM working-set
model (the hard "fits on chip" constraint), then timed on the live kernel,
and the winner is persisted via ``cache.PlanCache`` so every later call
with the same bucket reuses it instead of DEFAULT_BLOCKS.  The streaming
bandwidth model B_req (Eq. 5) is reported by ``benchmarks/bench_tile.py``
rather than used as a filter — on interpret-mode hosts wall time already
reflects the real constraint, and on TPU a bandwidth-starved tile simply
times worse.

Resource models (re-derived for the TPU port, previously inlined in
``benchmarks/bench_tile.py`` which now imports them from here):

  F_peak = peak_f32_flops / flops_per_dd_fma            (VPU path)
  B_req  = (bm + bn) / (bm * bn) * F_peak / 2 * 32 B/s  (stream A and B)
  VMEM   = 2 limbs * limb_bytes * (bm*bk + bk*bn + 2*bm*bn)
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mp
from . import cache as plan_cache
from .plan import GemmPlan, PRECISIONS, _clamp_blocks, make_plan, \
    resolve_backend

__all__ = [
    "autotune", "candidate_blocks", "vmem_bytes", "bandwidth_req_gbps",
    "FLOPS_PER_DD_FMA", "V5E_F32_FLOPS", "VMEM_BYTES", "HBM_GBPS",
]

# measured static op count of one DD multiply-add (two_prod + dd add chain)
FLOPS_PER_DD_FMA = 86
V5E_F32_FLOPS = 197e12 / 2   # VPU f32 is ~half the bf16 MXU rate
VMEM_BYTES = 16 * 2**20      # v5e per-core VMEM
HBM_GBPS = 819               # v5e HBM bandwidth

# sweep grid: the bench_tile shapes plus the skinny-K variants the LU
# trailing updates (k = panel width 8..64) actually hit
_SWEEP: Tuple[Tuple[int, int, int], ...] = (
    (32, 32, 8), (32, 32, 32), (64, 64, 8), (64, 64, 16), (64, 64, 32),
    (128, 128, 8), (128, 128, 16), (128, 128, 64), (128, 256, 16),
    (256, 128, 16),
)


def vmem_bytes(bm: int, bn: int, bk: int, limb_bytes: int = 4,
               nlimbs: int = 2) -> int:
    # a-tile + b-tile + 2 accumulators, one plane per limb
    return nlimbs * limb_bytes * (bm * bk + bk * bn + 2 * bm * bn)


def bandwidth_req_gbps(bm: int, bn: int, f_peak_flops: float) -> float:
    return (bm + bn) / (bm * bn) * f_peak_flops / 2 * 32 / 1e9


def f_peak_gflops() -> float:
    """Model binary128-class peak on the VPU path (GFlop/s)."""
    return V5E_F32_FLOPS / FLOPS_PER_DD_FMA / 1e9


def candidate_blocks(m: int, k: int, n: int,
                     limb_bytes: int = 4, nlimbs: int = 2) -> List[dict]:
    """Sweep candidates clamped to the problem and filtered by VMEM fit.

    The fit model scales with the limb count, so the qd tier's feasible set
    is roughly the dd set shrunk one tile size — tuned independently.
    """
    out, seen = [], set()
    for bm, bn, bk in _SWEEP:
        blk = _clamp_blocks(m, k, n, {"bm": bm, "bn": bn, "bk": bk})
        key = (blk["bm"], blk["bn"], blk["bk"])
        if key in seen:
            continue
        seen.add(key)
        if vmem_bytes(**blk, limb_bytes=limb_bytes,
                      nlimbs=nlimbs) < VMEM_BYTES:
            out.append(blk)
    return out


def _time_once(fn, warmup: int = 1, iters: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _expand_slice_candidates(m: int, k: int, n: int, blocks: Sequence[dict],
                             dtype, precision: str) -> List[dict]:
    """Cross block candidates with ``n_slices`` for the slicing kernel.

    Per block shape the exactness fixpoint fixes the MINIMUM slice count
    for the slab depth; the sweep also tries one extra slice (more dots,
    but finer slices sometimes win on accuracy-irrelevant grounds like
    concat sizes).  Counts below the minimum would silently lose bits, so
    they are never candidates.
    """
    out = []
    for blk in blocks:
        base = make_plan(m, k, n, dtype=dtype, precision=precision,
                         backend="ozaki-pallas", use_cache=False, **blk)
        if base.backend != "ozaki-pallas":
            continue  # slicing infeasible for this problem: plan fell back
        for ns in (base.n_slices, base.n_slices + 1):
            out.append(dict(blk, n_slices=ns))
    return out


def autotune(m: int, k: int, n: int, *, dtype=jnp.float64,
             precision: str = "dd", backend: str = "pallas",
             batch_shape: Tuple[int, ...] = (),
             candidates: Optional[Sequence[dict]] = None,
             cache: Optional[plan_cache.PlanCache] = None,
             seed: int = 0, iters: int = 2, persist: bool = True) -> GemmPlan:
    """Sweep block shapes on live data, persist the winner, return its plan.

    Returns the tuned ``GemmPlan`` for the (m, k, n) problem at the given
    precision tier; subsequent ``make_plan`` calls in the same (shape
    bucket, limb count, batch bucket) pick the entry up from the cache
    automatically.  ``batch_shape`` times the sweep on vmap-batched
    operands and persists under the batched bucket (schema v3 keys batched
    plans apart from the 2-D bucket — this is the API that populates
    them).  For ``backend="ozaki-pallas"`` the search space is block
    shapes x ``n_slices`` (never below the exactness minimum) and the
    winner's slice count is persisted alongside its blocks.
    """
    dtype = jnp.dtype(dtype)
    nlimbs = PRECISIONS[precision]
    backend = resolve_backend(backend)  # key the cache on the resolved name
    cache = cache or plan_cache.default_cache()
    if candidates is not None:
        candidates = list(candidates)
    else:
        candidates = candidate_blocks(m, k, n, limb_bytes=dtype.itemsize,
                                      nlimbs=nlimbs)
        if backend == "ozaki-pallas":
            candidates = _expand_slice_candidates(m, k, n, candidates,
                                                  dtype, precision)
    if not candidates:
        raise ValueError(f"no feasible block candidates for {(m, k, n)}")

    from . import engine

    rng = np.random.default_rng(seed)
    batch_shape = tuple(batch_shape)
    a = mp.from_float(
        jnp.asarray(rng.random(batch_shape + (m, k)) - 0.5, dtype),
        precision)
    b = mp.from_float(jnp.asarray(rng.random((k, n)) - 0.5, dtype), precision)

    best, best_t = None, float("inf")
    for cand in candidates:
        blk = {x: cand[x] for x in ("bm", "bn", "bk")}
        plan = make_plan(m, k, n, dtype=dtype, precision=precision,
                         backend=backend, batch_shape=batch_shape,
                         use_cache=False,
                         n_slices=cand.get("n_slices"), **blk)
        t = _time_once(lambda: engine.execute(plan, a, b), iters=iters)
        if t < best_t:
            best, best_t = plan, t

    if persist:
        # the entry lands under the bucket that was actually timed: the
        # 2-D (b1) bucket by default, or the vmap-batched bucket when a
        # batch_shape was swept (cache schema v3 keys them apart — their
        # VMEM pressure differs by the batch factor)
        key = plan_cache.cache_key(best.platform, dtype.name, m, k, n,
                                   backend, nlimbs=nlimbs,
                                   batch_shape=batch_shape)
        entry = {"bm": best.bm, "bn": best.bn, "bk": best.bk,
                 "us_per_call": best_t * 1e6,
                 "bucket": plan_cache.shape_bucket(m, k, n)}
        if best.backend == "ozaki-pallas" and best.n_slices:
            entry["n_slices"] = int(best.n_slices)
        cache.put(key, entry)
    return best.with_(source="tuned")
