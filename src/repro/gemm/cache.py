"""On-disk plan cache: tuned block shapes keyed by (shape-bucket, dtype, platform).

The paper fixes one (M_Tile, PE-array) configuration at synthesis time; the
TPU port instead tunes block shapes at runtime and must not re-tune for
every call.  This cache is the synthesis artifact's software analogue: a
JSON file mapping schema-versioned ``vN/platform/dtype/bucket/backend``
keys to the winning ``(bm, bn, bk)`` — plus, for the slicing kernel, the
tuned ``n_slices`` — so `rgetrf`'s trailing updates, SDP's `rsyrk`-shaped
calls, and repeated service traffic all reuse one tuned tile per shape
bucket.

Shapes are bucketed to the next power of two per dimension, so a 500x500x500
and a 512x512x512 GEMM share a tuning entry — the same coarsening the paper
applies by synthesizing one design per M_Tile rather than per matrix size.

Location: ``$REPRO_GEMM_CACHE`` if set, else ``~/.cache/repro/gemm_plans.json``.
Writes are atomic (tmp + rename) so concurrent benchmark shards can't tear
the file.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
import warnings
from typing import Optional

__all__ = ["PlanCache", "default_cache", "set_default_cache", "shape_bucket",
           "batch_bucket", "cache_key", "SCHEMA",
           "quarantine_key", "quarantine", "quarantined", "clear_quarantine",
           "QUARANTINE_TTL"]

_ENV_VAR = "REPRO_GEMM_CACHE"

# how long a quarantined backend stays benched (seconds).  A lowering
# failure is usually environmental (missing Mosaic support, an OOM-prone
# driver) and those heal across upgrades/reboots, not within a run — one
# day keeps a doomed backend from being re-attempted by every process on
# the box while still self-healing without manual cache surgery.
QUARANTINE_TTL = float(os.environ.get("REPRO_QUARANTINE_TTL", 86400.0))

# entry-schema version, embedded in every key.  v2: entries may carry an
# ``n_slices`` field (tuned alongside the blocks for the ozaki-pallas
# backend).  v3: keys fold in a batch bucket — a vmap-batched call runs
# ``prod(batch)`` kernel instances concurrently, so its VMEM pressure (and
# winning tile) differs from the 2-D bucket's by the batch factor; sharing
# one row silently reused 2-D tiles for batched work.  v4: the dtype
# segment always spells the limb count (``float64x2``, not bare
# ``float64`` for dd) — with the count-generic tier family the count is a
# first-class key axis, and the old dd-implicit spelling would collide
# with any future 2-limb format variant.  Bumping the version orphans old
# entries instead of letting them half-describe a plan: stale ``v3/...``
# rows are simply never consulted again (plans degrade to heuristics and
# re-tune), and stale quarantine rows are versioned separately below.
SCHEMA = 4


def _next_pow2(x: int, floor: int = 8) -> int:
    x = max(int(x), floor)
    return 1 << (x - 1).bit_length()


def shape_bucket(m: int, k: int, n: int) -> str:
    """Coarsen a problem shape to its power-of-two bucket."""
    return f"{_next_pow2(m)}x{_next_pow2(k)}x{_next_pow2(n)}"


def batch_bucket(batch_shape=()) -> str:
    """Coarsen a vmap batch shape to its power-of-two size bucket.

    ``b1`` is the plain 2-D call; a batched call buckets on the flattened
    batch size (a (2, 3) batch and a (6,) batch stress VMEM identically).
    """
    size = 1
    for d in batch_shape:
        size *= int(d)
    return f"b{_next_pow2(size, floor=1)}"


def cache_key(platform: str, dtype_name: str, m: int, k: int, n: int,
              backend: str, nlimbs: int = 2, batch_shape=()) -> str:
    """Cache key for one tuning bucket (schema-versioned).

    Keys on the limb count so precision tiers tune independently (a QD tile
    streams twice the limb planes of a DD tile and wants different blocks),
    on the batch bucket so vmap-batched plans tune apart from the 2-D
    bucket (their VMEM pressure differs by the batch factor), and on
    ``SCHEMA`` so entries written under an older entry layout are orphaned
    rather than misread.
    """
    dt = f"{dtype_name}x{nlimbs}"
    return (f"v{SCHEMA}/{platform}/{dt}/{batch_bucket(batch_shape)}/"
            f"{shape_bucket(m, k, n)}/{backend}")


class PlanCache:
    """JSON-backed block-shape cache with an in-memory write-through layer."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get(_ENV_VAR) or os.path.join(
            os.path.expanduser("~"), ".cache", "repro", "gemm_plans.json")
        self._lock = threading.Lock()
        self._mem: Optional[dict] = None

    def _load(self) -> dict:
        if self._mem is None:
            try:
                with open(self.path) as f:
                    data = json.load(f)
            except OSError:
                data = {}  # no cache yet: the normal cold-start path
            except ValueError as e:
                # a corrupt/truncated file (killed writer, hand edit, disk
                # hiccup) must cost a warning and a retune, never an
                # exception in every GEMM that consults the cache
                warnings.warn(
                    f"ignoring corrupt GEMM plan cache {self.path!r} "
                    f"({e}); plans fall back to heuristics until re-tuned",
                    RuntimeWarning, stacklevel=3)
                data = {}
            if not isinstance(data, dict):
                warnings.warn(
                    f"GEMM plan cache {self.path!r} is not a JSON object "
                    f"(got {type(data).__name__}); ignoring it",
                    RuntimeWarning, stacklevel=3)
                data = {}
            self._mem = data
        return self._mem

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            entry = self._load().get(key)
        return dict(entry) if isinstance(entry, dict) else None

    def put(self, key: str, entry: dict) -> None:
        with self._lock:
            # re-read the file before writing so sequential tuners (and the
            # common run-then-run case) merge rather than clobber; the
            # rename below keeps the JSON untorn.  A true concurrent
            # interleaving can still lose the slower writer's entry — an
            # accepted cost for a tuning hint, which the loser re-derives.
            self._mem = None
            data = self._load()
            data[key] = dict(entry)
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._write_locked(data)

    def _write_locked(self, data: dict) -> None:
        """Atomically replace the cache file with ``data`` (lock held).

        Write-temp + ``os.replace`` in the destination directory, with an
        fsync before the rename: a writer killed at ANY point leaves
        either the old complete file or the new complete file — never a
        truncation — and a crash right after the rename cannot surface a
        zero-length file from an unflushed page cache.  The chaos suite's
        killed-writer injection asserts exactly this.
        """
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(self.path) or ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def drop_prefix(self, prefix: str) -> int:
        """Remove every entry whose key starts with ``prefix``; persist.

        The quarantine lifecycle's release valve: ``clear_quarantine``
        drops the ``quarantine/`` namespace without touching tuned blocks.
        Returns the number of entries dropped.
        """
        with self._lock:
            self._mem = None
            data = self._load()
            doomed = [k for k in data if k.startswith(prefix)]
            for k in doomed:
                del data[k]
            if doomed:
                os.makedirs(os.path.dirname(self.path) or ".",
                            exist_ok=True)
                self._write_locked(data)
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            try:
                os.unlink(self.path)
            except OSError:
                pass


_default: Optional[PlanCache] = None
_default_explicit = False
_default_lock = threading.Lock()


def default_cache() -> PlanCache:
    global _default
    with _default_lock:
        if _default is None:
            _default = PlanCache()
        elif not _default_explicit:
            # re-resolve env-derived caches (both set AND unset transitions):
            # a cache installed via set_default_cache must win over
            # $REPRO_GEMM_CACHE, but an env-derived one tracks the env var
            if _default.path != PlanCache().path:
                _default = PlanCache()
        return _default


def set_default_cache(cache: Optional[PlanCache]) -> None:
    """Override the process-wide cache (tests point this at tmp dirs)."""
    global _default, _default_explicit
    with _default_lock:
        _default = cache
        _default_explicit = cache is not None


# --------------------------------------------------------------------------
# backend quarantine: failed backends benched in the same cache file
# --------------------------------------------------------------------------
#
# When a kernel backend fails at compile/run time the engine fails over
# down the plan's fallback chain — but re-attempting the doomed backend on
# every call re-pays the (often seconds-long) lowering failure.  The
# quarantine records "backend X is broken on platform P at N limbs" in the
# same JSON the tuner writes, so repeat calls (and fresh processes) skip
# the attempt at *plan* time.  Entries carry the failure reason and a
# timestamp; they expire after QUARANTINE_TTL so an upgraded toolchain
# heals without manual intervention.  Namespaced under ``quarantine/v1``
# so ``clear_quarantine`` can drop them without touching tuned blocks.

_QUAR_PREFIX = "quarantine/v1"


def quarantine_key(platform: str, backend: str, nlimbs: int = 2) -> str:
    """Quarantine entries key coarser than tuning entries: a backend that
    cannot lower for (platform, limb count) is broken for every shape."""
    return f"{_QUAR_PREFIX}/{platform}/{backend}/x{nlimbs}"


def quarantine(platform: str, backend: str, nlimbs: int = 2, *,
               reason: str = "", cache: Optional[PlanCache] = None) -> None:
    """Bench a backend for (platform, limb count) for QUARANTINE_TTL."""
    (cache or default_cache()).put(
        quarantine_key(platform, backend, nlimbs),
        {"reason": str(reason)[:500], "unix_time": time.time()})


def quarantined(platform: str, backend: str, nlimbs: int = 2, *,
                cache: Optional[PlanCache] = None) -> Optional[dict]:
    """The live quarantine entry for a backend, or None.

    Expired entries answer None (they are left on disk; the next
    ``quarantine``/``clear_quarantine`` write compacts them).
    """
    entry = (cache or default_cache()).get(
        quarantine_key(platform, backend, nlimbs))
    if not entry:
        return None
    try:
        age = time.time() - float(entry.get("unix_time", 0.0))
    except (TypeError, ValueError):
        return None  # malformed timestamp: treat as expired, not fatal
    if age > QUARANTINE_TTL:
        return None
    return entry


def clear_quarantine(cache: Optional[PlanCache] = None) -> int:
    """Lift every quarantine (``repro.gemm.clear_quarantine()`` is the
    documented remedy once the environment is fixed).  Returns the count."""
    return (cache or default_cache()).drop_prefix(_QUAR_PREFIX)
