"""Execution engine: one dispatcher for every extended-precision GEMM.

``execute(plan, a, b)`` routes a planned workload to its backend kernel.
Operands are multi-limb struct-of-arrays values — ``dd.DD`` for the
``precision="dd"`` tier (2 limbs, binary128 class), ``td.TD`` for
``precision="td"`` (3 limbs, ~159 bits), or ``qd.QD`` for
``precision="qd"`` (4 limbs, binary128+) — and every capability of the
engine is limb-count generic:

  * **batched GEMM** — leading batch dimensions on either operand are
    flattened and vmapped over the planned 2-D kernel, so SDP's
    per-constraint ``X @ (A_j Z^-1)`` stacks run as one call instead of a
    Python loop over constraints;
  * **sharded GEMM** — with a mesh in the plan, execution is a SUMMA-style
    2-D distribution via ``shard_map``: C's row blocks shard over
    ``plan.shard_axis``, its column blocks over ``plan.shard_axis_n``, and
    a ``lax.fori_loop`` walks the K dimension in ``k_panel``-deep steps,
    replicating the owning device's A row-panel along the column axis and
    B column-panel along the row axis per step and accumulating into a
    local C' block in tier arithmetic.  Panel movement is a double-
    buffered ``lax.ppermute`` ring by default (``plan.comm="ring"``: the
    next step's panels travel hop-by-hop while the current dot runs; the
    loop is seeded by pre-rotating panel 0 — Cannon-style starting
    alignment), with the legacy exact masked-psum broadcast selectable as
    ``comm="psum"``; the two schedules are bit-identical.  This is the
    software analogue of the paper's DDR→BRAM panel streaming; the output
    *stays* 2-D block-sharded (``P(axis_m, axis_n)``) — no all-gather on
    the result, matching the paper's Feed/Drain streaming where C' tiles
    drain independently.  A 1-axis mesh degenerates to the old
    row-sharded layout, batched + sharded calls compose ``vmap`` outside
    the ``shard_map``, and ``plan.k_stream`` adds host-side out-of-core K
    streaming on top (chunks of A/B feed through the runner while the C'
    accumulator stays device-resident — bit-identical to the unstreamed
    run).

Backend kernels per tier: the count-generic Pallas systolic tile
(``kernels/mlgemm.py`` — one tile schedule, ``nlimbs`` limb planes), the
fused Ozaki-slice Pallas kernel (``kernels/ozgemm.py`` — every tier,
slice-pair dots on the matrix unit with in-VMEM recombination), the
blocked-XLA fallbacks, the O(m*k*n) oracles, and — dd/td only — the
whole-K Ozaki slicing path.  Padding to block multiples is exact in multi-limb
arithmetic (zeros carry no rounding), so the engine owns all
pad/clamp/slice logic.

The engine also owns the Rgemm **alpha/beta epilogue**: ``execute``/
``matmul`` accept optional ``alpha``/``beta``/``c`` operands.  On the
``ozaki-pallas`` 2-D path the epilogue is fused into the kernel's drain
step (the C' tile is scaled and combined before it leaves VMEM); every
other path applies the identical tier arithmetic as a post-step, so
results match cell-for-cell across backends.
"""

from __future__ import annotations

import functools
import math
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mp
from repro.runtime import faults as _faults
from repro.runtime.faults import (BackendExecutionError,
                                  BackendFailoverWarning,
                                  NumericalHazardError)

from . import cache as plan_cache
from . import guard
from .plan import (GemmPlan, fallback_chain, make_plan,
                   round_up as _round_up)

__all__ = ["execute", "matmul"]

# kill switch for backend failover: REPRO_GEMM_FAILOVER=0 makes a backend
# failure raise immediately (bisection wants the original traceback, not a
# masked recovery)
_ENV_FAILOVER = "REPRO_GEMM_FAILOVER"


def _pad_to(x, rows, cols):
    r, c = x.shape[-2:]
    if r == rows and c == cols:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, rows - r), (0, cols - c)]
    return jnp.pad(x, pad)


# optimization_barrier has no batching rule in jax 0.4.x; it is identity
# on values, so vmap passes straight through (the batched-GEMM vmap over
# _pad would otherwise raise NotImplementedError).  jax >= 0.5 registers
# its own rule, making this shim obsolete — the absence guard below keeps
# us from overriding it.  Registration mutates a private jax dict, so it
# is best-effort: if the internals move, _pad falls back to skipping the
# barrier under vmap (see the NotImplementedError handler there), which
# costs const-closure bit-reproducibility for batched operands, never
# correctness.
try:
    from jax.interpreters import batching as _batching  # noqa: E402

    if jax.lax.optimization_barrier_p not in _batching.primitive_batchers:
        def _ob_batch(vals, dims):
            return jax.lax.optimization_barrier_p.bind(*vals), dims

        _batching.primitive_batchers[jax.lax.optimization_barrier_p] = \
            _ob_batch
except Exception:  # pragma: no cover - depends on jax internals moving
    pass


def _pad(x, rows, cols):
    r, c = x.shape[-2:]
    if r == rows and c == cols:
        return x
    padded = mp.map_limbs(lambda l: _pad_to(l, rows, cols), x)
    # the barrier pins the padded limbs as opaque runtime values.  Without
    # it, operands that are trace-time CONSTANTS under an outer jit lose
    # bit-reproducibility: XLA's constant folder refuses to fold through
    # the output-enlarging pad, and the surviving constant-fed fusions
    # rewrite the downstream error-free-transformation chains
    # value-changingly (~1e-17 relative drift vs the same call un-jitted,
    # first seen on interpret-mode ozaki-pallas).  Pinning the pad output
    # makes the compiled graph per-op-faithful, so jit(const-closure),
    # jit(args), and eager all produce identical limbs.
    try:
        return mp.from_limbs(jax.lax.optimization_barrier(
            tuple(mp.limbs(padded))))
    except NotImplementedError:
        # under vmap on a jax whose batching registry rejected our shim:
        # skip the barrier rather than fail (trace-time fallback)
        return padded


# --------------------------------------------------------------------------
# 2-D backend dispatch
# --------------------------------------------------------------------------


def _execute_pallas(plan: GemmPlan, a, b):
    from .plan import _clamp_blocks

    m, k = a.shape
    _, n = b.shape
    # re-clamp against the *actual* shapes: sharded execution hands each
    # device a row panel smaller than the global problem the plan saw
    blk = _clamp_blocks(m, k, n, plan.blocks)
    bm, bn, bk = blk["bm"], blk["bn"], blk["bk"]
    mpad, npad, kpad = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_p, b_p = _pad(a, mpad, kpad), _pad(b, kpad, npad)
    from repro.kernels.mlgemm import mlgemm_kernel_call

    out = mlgemm_kernel_call(*mp.limbs(a_p), *mp.limbs(b_p),
                             bm=bm, bn=bn, bk=bk,
                             interpret=plan.interpret)
    return mp.from_limbs([o[:m, :n] for o in out])


def _ozaki_pallas_params(plan: GemmPlan, bk: int):
    """(beta, n_slices, slice_dtype_name, acc_dtype_name) for a slab depth.

    The plan solved (beta, n_slices) for its own bk; a re-clamped smaller
    slab only gains exactness headroom, so the planned values stay valid.
    Hand-built plans without solved parameters get them solved here.
    """
    from repro.core import ozaki as _ozaki

    slice_dtype = jnp.dtype(plan.slice_dtype) if plan.slice_dtype \
        else jnp.float64
    acc_dtype = jnp.dtype(plan.acc_dtype) if plan.acc_dtype else jnp.float64
    beta, n_slices = plan.slice_beta, plan.n_slices
    if beta is None or n_slices is None:
        from .plan import OZAKI_TARGET_BITS

        beta, n_slices = _ozaki.slice_params(
            bk, acc_dtype, slice_dtype,
            target_bits=plan.target_bits or OZAKI_TARGET_BITS[plan.precision],
            n_slices=n_slices, beta=beta)
    return beta, n_slices, slice_dtype.name, acc_dtype.name


def _execute_ozaki_pallas(plan: GemmPlan, a, b, alpha=None, beta=None,
                          c=None):
    """The fused Ozaki-slice kernel, optionally with the in-drain epilogue."""
    from .plan import _clamp_blocks
    from repro.kernels.ozgemm import ozgemm_kernel_call

    _faults.poke("backend.ozaki-pallas")

    m, k = a.shape
    _, n = b.shape
    blk = _clamp_blocks(m, k, n, plan.blocks)
    bm, bn, bk = blk["bm"], blk["bn"], blk["bk"]
    sbeta, n_slices, sdt, adt = _ozaki_pallas_params(plan, bk)
    mpad, npad, kpad = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    operands = list(mp.limbs(_pad(a, mpad, kpad)))
    operands += list(mp.limbs(_pad(b, kpad, npad)))
    epilogue = "none"
    if alpha is not None:
        epilogue = "alpha" if c is None else "full"
        operands += [l.reshape(1, 1) for l in mp.limbs(alpha)]
        if c is not None:
            operands += [l.reshape(1, 1) for l in mp.limbs(beta)]
            operands += list(mp.limbs(_pad(c, mpad, npad)))
    out = ozgemm_kernel_call(*operands, bm=bm, bn=bn, bk=bk, beta=sbeta,
                             n_slices=n_slices, slice_dtype_name=sdt,
                             acc_dtype_name=adt, epilogue=epilogue,
                             full=bool(plan.full),
                             interpret=plan.interpret)
    return mp.from_limbs([o[:m, :n] for o in out])


def _execute_2d(plan: GemmPlan, a, b):
    if plan.backend == "ozaki-pallas":
        return _execute_ozaki_pallas(plan, a, b)  # pokes its own site
    # chaos hook: a "backend.<name>" injection models this kernel failing
    # to lower/run (fires at trace time, so failed traces are never cached)
    _faults.poke("backend." + plan.backend)
    if plan.backend == "pallas":
        return _execute_pallas(plan, a, b)
    if plan.backend == "ozaki":
        if plan.precision == "qd":
            raise ValueError("ozaki backend has no qd tier (make_plan "
                             "should have rerouted or rejected this plan)")
        from repro.core.ozaki import ozaki_gemm

        kw = {}
        if plan.slice_dtype:
            kw["slice_dtype"] = jnp.dtype(plan.slice_dtype)
        if plan.acc_dtype:
            kw["acc_dtype"] = jnp.dtype(plan.acc_dtype)
        if plan.n_slices is not None:
            kw["n_slices"] = plan.n_slices
        if plan.slice_beta is not None:
            kw["beta"] = plan.slice_beta
        if plan.target_bits is not None:
            kw["target_bits"] = plan.target_bits
        elif plan.precision != "dd":
            # hand-built plan without a solved target: cover the tier's own
            # significand, not ozaki_gemm's dd-oriented default
            from .plan import OZAKI_TARGET_BITS

            kw["target_bits"] = OZAKI_TARGET_BITS[plan.precision]
        if plan.full is not None:
            kw["full"] = plan.full
        return ozaki_gemm(a, b, **kw)
    if plan.backend == "xla":
        from repro.kernels.ops import matmul_ml_xla

        return matmul_ml_xla(a, b, chunk=plan.bk)
    if plan.backend == "ref":
        from repro.kernels.ref import mlgemm_ref

        return mlgemm_ref(a, b)
    raise ValueError(f"unknown backend in plan: {plan.backend!r}")


# --------------------------------------------------------------------------
# batched execution (leading batch dims -> vmap over the planned kernel)
# --------------------------------------------------------------------------


def _execute_batched(plan: GemmPlan, a, b, inner=None):
    """vmap ``inner`` (default: the planned 2-D kernel) over batch dims.

    ``inner`` is the per-matrix execution body; the sharded path passes the
    SUMMA ``shard_map`` runner here, composing vmap *outside* the shard_map
    so batched + sharded is one call (shard_map has a batching rule).
    """
    inner = inner or (lambda x, y: _execute_2d(plan, x, y))
    a_batch = a.shape[:-2]
    b_batch = b.shape[:-2]
    batch = jnp.broadcast_shapes(a_batch, b_batch)
    nb = math.prod(batch)

    def flat(x, had_batch):
        if not had_batch:
            return x
        tgt = batch + x.shape[-2:]
        return mp.map_limbs(
            lambda l: jnp.broadcast_to(l, tgt).reshape((nb,) + l.shape[-2:]),
            x)

    af = flat(a, bool(a_batch))
    bf = flat(b, bool(b_batch))
    # DD/QD are NamedTuple pytrees: in_axes=0 maps every limb plane
    fn = jax.vmap(inner,
                  in_axes=(0 if a_batch else None, 0 if b_batch else None))
    out = fn(af, bf)
    m, n = out.shape[-2:]
    return mp.map_limbs(lambda l: l.reshape(batch + (m, n)), out)


# jit wrappers keyed on the (frozen, hashable) plan: without these, every
# eager call re-traces the backend's scan/vmap/pallas graph — at the qd
# tier that retrace is thousands of ops and dominates wall time (observed
# in the SDP inner loop).  The alpha/beta/c epilogue operands ride inside
# the same jit (None is an empty pytree, so epilogue-free calls compile
# their own specialization): an eager post-step epilogue at the qd tier is
# hundreds of per-limb ops per call, which dominated the refinement
# solver's residual r = b - A x.  The mesh field is excluded from plan
# equality/hash, so only the mesh-free paths go through here; sharded
# execution compiles inside shard_map as before.


# Each wrapper returns ``(out, flags)``: the guard's hazard flags are a
# few extra reductions traced into the SAME compiled graph (``check`` is a
# static key, so unguarded calls compile flag-free specializations).  One
# dispatch total — this is what keeps check="finite" inside its ≤15%
# overhead budget; a separate probe dispatch would double the fixed cost
# on small cells.


@functools.partial(jax.jit, static_argnames=("plan", "check"))
def _execute_2d_jit(a, b, alpha, beta, c, *, plan: GemmPlan,
                    check: str = "none"):
    out = _apply_epilogue(_execute_2d(plan, a, b), alpha, beta, c)
    return out, guard.hazard_flags(plan, a, b, c, out, alpha, beta, check)


@functools.partial(jax.jit, static_argnames=("plan", "check"))
def _execute_batched_jit(a, b, alpha, beta, c, *, plan: GemmPlan,
                         check: str = "none"):
    out = _apply_epilogue(_execute_batched(plan, a, b), alpha, beta, c)
    return out, guard.hazard_flags(plan, a, b, c, out, alpha, beta, check)


@functools.partial(jax.jit, static_argnames=("plan", "check"))
def _execute_fused_alpha_jit(a, b, alpha, *, plan: GemmPlan,
                             check: str = "none"):
    out = _execute_ozaki_pallas(plan, a, b, alpha=alpha)
    return out, guard.hazard_flags(plan, a, b, None, out, alpha, None,
                                   check)


@functools.partial(jax.jit, static_argnames=("plan", "check"))
def _execute_fused_full_jit(a, b, alpha, beta, c, *, plan: GemmPlan,
                            check: str = "none"):
    out = _execute_ozaki_pallas(plan, a, b, alpha=alpha, beta=beta, c=c)
    return out, guard.hazard_flags(plan, a, b, c, out, alpha, beta, check)


# --------------------------------------------------------------------------
# alpha/beta epilogue (paper Eq. 1, host side of the Rgemm split)
# --------------------------------------------------------------------------


def _as_scalar(x, precision: str, dtype):
    """Coerce a python float / multi-limb scalar to the operands' tier."""
    try:
        return mp.promote(x, precision)
    except TypeError:
        return mp.from_float(jnp.asarray(x, dtype), precision)


def _static_zero(x) -> bool:
    """True iff ``x`` is *statically known* to be zero.

    Python numbers answer directly; concrete arrays / multi-limb scalars
    are inspected limb-wise.  A traced value answers False — it may still
    be zero at runtime, which the ``where``-guard in ``_apply_epilogue``
    (and the fused kernel drain) handles without reading C's values.
    """
    if x is None:
        return False
    if isinstance(x, (int, float)):
        return x == 0
    try:
        ls = mp.limbs(x)
    except TypeError:
        ls = [x]
    try:
        import numpy as np

        return all(not np.any(np.asarray(l)) for l in ls)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return False


def _apply_epilogue(out, alpha, beta, c):
    """out = alpha * out [+ beta * c] in the operands' own tier — the
    post-step form, numerically identical to the kernel-fused drain.

    BLAS semantics: ``beta == 0`` means C is **not read** — a NaN/Inf in C
    must not leak through ``0 * C``.  Statically-zero betas never reach
    here (``execute`` drops C outright); a *traced* beta that is zero at
    runtime is handled by masking the ``beta * C`` term with a select, so
    the NaN produced by ``0 * NaN`` is discarded, not propagated.
    """
    if alpha is not None:
        out = mp.mul(mp.broadcast_to(alpha, out.shape), out)
    if c is not None:
        bc = mp.mul(mp.broadcast_to(beta, c.shape), c)
        bc = mp.where(jnp.broadcast_to(mp.is_zero(beta), bc.shape),
                      mp.map_limbs(jnp.zeros_like, bc), bc)
        out = mp.add(out, bc)
    return out


# pure pytree arithmetic — jittable without the plan key, so the sharded
# path (whose shard_map compiles outside the plan-keyed wrappers because
# plan equality/hash excludes the mesh) still gets a compiled epilogue
# instead of hundreds of eager per-limb ops per call
_apply_epilogue_jit = jax.jit(_apply_epilogue)


# --------------------------------------------------------------------------
# sharded execution: SUMMA-style 2-D distribution, all-gather-free output
# --------------------------------------------------------------------------


def _summa_geometry(plan: GemmPlan, k: int):
    """(pr, pc, lcm, kp): mesh extents and the effective SUMMA panel depth.

    One definition for runner and K-streamer: the host-side out-of-core
    loop must slice its chunks on the very panel grid the runner walks,
    or streamed and unstreamed execution would fold different panel
    products (bit-exactness would be lost).
    """
    mesh, ax_m, ax_n = plan.mesh, plan.shard_axis, plan.shard_axis_n
    pr = mesh.shape[ax_m] if ax_m is not None else 1
    pc = mesh.shape[ax_n] if ax_n is not None else 1
    lcm = math.lcm(pr, pc)
    # panel depth never exceeds a device's K chunk, so a small-K problem
    # does not pad its K dimension up to a full (oversized) panel
    kp = max(1, min(plan.k_panel or plan.bk, -(-k // lcm)))
    return pr, pc, lcm, kp


def _summa_runner(plan: GemmPlan, m: int, k: int, n: int, nl: int):
    """Build the ``shard_map``-wrapped SUMMA loop for one global shape.

    Layout (the classic SUMMA block distribution, DESIGN.md §11):

      * A's rows shard over ``shard_axis`` (Pr), its K columns over
        ``shard_axis_n`` (Pc);
      * B's K rows shard over ``shard_axis`` (Pr), its columns over
        ``shard_axis_n`` (Pc);
      * C' blocks live at ``P(shard_axis, shard_axis_n)`` and never move.

    Each of the ``Kpad / k_panel`` K-steps replicates the owning column's
    A row-panel along ``shard_axis_n`` and the owning row's B column-panel
    along ``shard_axis``, then folds the local ``(m_loc, kp) @ (kp, n_loc)``
    panel product into the loop-carried accumulator with a tier add.  This
    is the engine's analogue of the paper's DDR→BRAM panel streaming: the
    carry is the BRAM-resident C' tile, the per-step panels are the
    streamed operands.  Two panel-movement schedules (``plan.comm``):

      * ``"ring"`` (default) — a ``lax.ppermute`` ring: the owner injects
        its panel and it travels hop-by-hop around the axis (keep-selects
        at each hop), pure data movement with no reduction arithmetic, and
        the loop carry **double-buffers** the in-flight panel — the hops
        for step ``t+1`` are issued before step ``t``'s dot retires, so
        communication overlaps compute.  The loop is seeded by
        pre-rotating panel 0 into the buffers (Cannon-style starting
        alignment).
      * ``"psum"`` — the legacy masked all-reduce (non-owners contribute
        exact zero limbs), kept selectable as the conformance reference:
        both schedules deliver bit-identical panels and fold them in the
        same global K order, so ring output is bit-identical to psum.

    Returns ``(run, (mpad, npad, kpad))`` where
    ``run(*a_limbs, *b_limbs, *acc_limbs)`` maps padded 2-D operands plus
    an initial (padded, block-sharded) accumulator to the padded,
    still-2-D-sharded ``acc + A @ B``.  Threading the accumulator through
    as an operand is what lets the out-of-core K-streamer continue the
    *same* left-to-right panel fold across host-sliced chunks.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, ax_m, ax_n = plan.mesh, plan.shard_axis, plan.shard_axis_n
    pr, pc, lcm, kp = _summa_geometry(plan, k)
    # K pads so every device's contiguous chunk is whole panels: A splits K
    # over the column axis, B over the row axis, so both chunkings must be
    # panel-aligned (zero padding is exact in multi-limb arithmetic)
    kpad = _round_up(k, kp * lcm)
    mpad, npad = _round_up(m, pr), _round_up(n, pc)
    ka, kb = kpad // pc, kpad // pr  # local K chunk held of A / of B
    steps = kpad // kp

    def local(*limbs):
        al = mp.from_limbs(limbs[:nl])           # (mpad/pr, ka)
        bl = mp.from_limbs(limbs[nl:2 * nl])     # (kb, npad/pc)
        acc0 = mp.from_limbs(limbs[2 * nl:])     # (mpad/pr, npad/pc)
        m_loc, n_loc = al.shape[0], bl.shape[1]
        ci = jax.lax.axis_index(ax_n) if ax_n is not None else None
        ri = jax.lax.axis_index(ax_m) if ax_m is not None else None

        def bcast_psum(panel, owner, me, axis_name):
            """Replicate the owner's panel along ``axis_name`` as a masked
            all-reduce (exact: non-owners contribute zero limbs)."""
            if axis_name is None:
                return panel
            return mp.map_limbs(
                lambda l: jax.lax.psum(
                    jnp.where(me == owner, l, jnp.zeros_like(l)),
                    axis_name), panel)

        def bcast_ring(panel, owner, me, axis_name, size):
            """Replicate the owner's panel along ``axis_name`` by walking
            it around a ``ppermute`` ring: at hop ``s`` the device at ring
            distance ``s`` downstream of the owner latches the in-flight
            panel and keeps forwarding it.  Pure data movement + selects —
            no reduction arithmetic — and each hop is one neighbor edge,
            so per-link traffic is one panel per step regardless of the
            axis size (vs the all-reduce's 2(size-1) panel transits)."""
            if axis_name is None or size == 1:
                return panel
            dist = (me - owner) % size
            perm = [(s, (s + 1) % size) for s in range(size)]
            # limbs coalesced into ONE buffer so each hop is a single wire
            # message (stack/unstack moves no bits, so conformance with
            # the per-limb psum path is unaffected); non-owners start with
            # their own (wrong) local slice, but a device at distance s
            # latches the in-flight value exactly at hop s — forwarded
            # from distance s-1, which latched the true panel one hop
            # earlier — so stale slices never propagate
            held = jnp.stack(tuple(mp.limbs(panel)))
            for s in range(1, size):
                fwd = jax.lax.ppermute(held, axis_name, perm)
                held = jnp.where(dist == s, fwd, held)
            return mp.from_limbs(tuple(held[i] for i in range(nl)))

        def fetch(t):
            """Slice + replicate the step-``t`` panels (both schedules
            deliver bit-identical panels; only the wire pattern differs)."""
            g = t * kp                          # global K offset of panel t
            own_a, off_a = g // ka, g % ka      # column owning A(:, panel t)
            own_b, off_b = g // kb, g % kb      # row owning B(panel t, :)
            apan = mp.map_limbs(
                lambda l: jax.lax.dynamic_slice(l, (0, off_a), (m_loc, kp)),
                al)
            bpan = mp.map_limbs(
                lambda l: jax.lax.dynamic_slice(l, (off_b, 0), (kp, n_loc)),
                bl)
            if plan.comm == "ring":
                apan = bcast_ring(apan, own_a, ci, ax_n, pc)
                bpan = bcast_ring(bpan, own_b, ri, ax_m, pr)
            else:
                apan = bcast_psum(apan, own_a, ci, ax_n)
                bpan = bcast_psum(bpan, own_b, ri, ax_m)
            return apan, bpan

        def hooks(apan, bpan, t):
            # chaos hooks: a "summa.panel.*" injection zeroes the chosen
            # K-step's panel AS USED (a lost broadcast / dropped ring
            # hop); inert identity without an armed FaultPlan, and
            # inject() drops the _summa_runner_jit cache so faulty traces
            # stay in scope
            return (_faults.zero_panel("summa.panel.a", apan, t),
                    _faults.zero_panel("summa.panel.b", bpan, t))

        if plan.comm == "ring":
            def step(t, carry):
                acc_l, ap_l, bp_l = carry
                # issue the NEXT panel's ring hops before this step's dot:
                # the in-flight ppermute overlaps the compute (the double
                # buffer is the loop carry)
                nxt_a, nxt_b = fetch(t + 1)
                apan, bpan = hooks(mp.from_limbs(ap_l),
                                   mp.from_limbs(bp_l), t)
                acc = mp.add(mp.from_limbs(acc_l),
                             _execute_2d(plan, apan, bpan))
                return (tuple(mp.limbs(acc)), tuple(mp.limbs(nxt_a)),
                        tuple(mp.limbs(nxt_b)))

            a0, b0 = fetch(jnp.asarray(0))  # pre-rotate to start alignment
            acc_l, ap_l, bp_l = jax.lax.fori_loop(
                0, steps - 1, step,
                (tuple(mp.limbs(acc0)), tuple(mp.limbs(a0)),
                 tuple(mp.limbs(b0))))
            # last step peeled: nothing left to prefetch, so the whole
            # schedule issues exactly `steps` panel broadcasts (same wire
            # traffic count as the psum schedule, minus the replication)
            apan, bpan = hooks(mp.from_limbs(ap_l), mp.from_limbs(bp_l),
                               steps - 1)
            acc = mp.add(mp.from_limbs(acc_l), _execute_2d(plan, apan, bpan))
            return tuple(mp.limbs(acc))

        def step(t, carry):
            apan, bpan = hooks(*fetch(t), t)
            acc = mp.add(mp.from_limbs(carry),
                         _execute_2d(plan, apan, bpan))
            return tuple(mp.limbs(acc))

        return jax.lax.fori_loop(0, steps, step, tuple(mp.limbs(acc0)))

    blk = P(ax_m, ax_n)
    run = shard_map(
        local, mesh=mesh,
        in_specs=(blk,) * (3 * nl),
        # the output stays 2-D block-sharded: each device drains its own C'
        # block, no all-gather — consumers slice or keep computing
        # shard-local (the paper's independent per-PE Feed/Drain)
        out_specs=(blk,) * nl,
        check_rep=False,
    )
    return run, (mpad, npad, kpad)


# compile-once cache for the SUMMA runner: shard_map applied eagerly
# re-traces its body every call (thousands of ops per limb at the qd tier —
# the cost the plan-keyed jit wrappers above exist to avoid), so the built
# runner is jitted and memoized.  The mesh must be part of the key
# explicitly: plan equality/hash EXCLUDES the mesh field, so two plans that
# compare equal can still target different meshes.
@functools.lru_cache(maxsize=128)
def _summa_runner_jit(plan: GemmPlan, mesh, m: int, k: int, n: int,
                      nl: int):
    assert mesh is plan.mesh or mesh == plan.mesh
    run, pads = _summa_runner(plan, m, k, n, nl)
    return jax.jit(run), pads


def _execute_sharded(plan: GemmPlan, a, b):
    nl = mp.nlimbs(a)
    m, k = a.shape[-2:]
    n = b.shape[-1]
    if plan.k_stream is not None and k > plan.k_stream:
        return _execute_k_stream(plan, a, b)
    run, (mpad, npad, kpad) = _summa_runner_jit(plan, plan.mesh, m, k, n,
                                                nl)

    def run2d(x, y):
        z = mp.zeros((mpad, npad), plan.precision,
                     dtype=mp.limbs(x)[0].dtype)
        out = run(*mp.limbs(_pad(x, mpad, kpad)),
                  *mp.limbs(_pad(y, kpad, npad)), *mp.limbs(z))
        if (mpad, npad) == (m, n):
            return mp.from_limbs(out)  # keeps the 2-D sharded layout
        return mp.from_limbs([l[:m, :n] for l in out])

    if len(a.shape) > 2 or len(b.shape) > 2:
        # batched + sharded: vmap composes OUTSIDE the shard_map — each
        # batch element runs the same SUMMA loop on the same mesh
        return _execute_batched(plan, a, b, inner=run2d)
    return run2d(a, b)


def _execute_k_stream(plan: GemmPlan, a, b):
    """Host-side out-of-core K streaming through the sharded SUMMA runner.

    The host slices A's columns / B's rows into ``k_stream``-deep chunks
    and feeds each through the runner, threading the block-sharded C'
    accumulator from chunk to chunk as the runner's carry operand — the
    software analogue of the paper's DDR-resident operand stream: only one
    chunk's worth of A/B panels is in flight at a time, while C' stays
    device-resident across the whole K walk.

    Bit-exactness vs the unstreamed run is by construction:

      * the chunk width rounds up to a multiple of the panel depth (and to
        at least one whole panel round, ``kp * lcm(pr, pc)``), so streamed
        panels slice at exactly the unstreamed run's global K offsets;
      * the per-chunk plan pins ``k_panel`` to the global run's effective
        panel depth, so a short tail chunk cannot re-derive a smaller one;
      * the tail chunk zero-pads host-side up to the common chunk width —
        zero panels fold as exact no-ops in tier arithmetic (and every
        chunk reuses the single compiled runner);
      * the carry threads through the runner, so the accumulator performs
        the SAME left-to-right panel fold as one unstreamed call.
    """
    nl = mp.nlimbs(a)
    m, k = a.shape[-2:]
    n = b.shape[-1]
    _, _, lcm, kp = _summa_geometry(plan, k)
    ks = max(_round_up(plan.k_stream, kp), kp * lcm)
    sub = plan.with_(k_stream=None, k_panel=kp)
    run, (mpad, npad, kpad) = _summa_runner_jit(sub, sub.mesh, m, ks, n,
                                                nl)

    def run2d(x, y):
        carry = mp.zeros((mpad, npad), plan.precision,
                         dtype=mp.limbs(x)[0].dtype)
        for s in range(0, k, ks):
            xc = mp.map_limbs(lambda l: l[:, s:s + ks], x)
            yc = mp.map_limbs(lambda l: l[s:s + ks, :], y)
            carry = mp.from_limbs(run(
                *mp.limbs(_pad(xc, mpad, kpad)),
                *mp.limbs(_pad(yc, kpad, npad)),
                *mp.limbs(carry)))
        if (mpad, npad) == (m, n):
            return carry
        return mp.from_limbs([l[:m, :n] for l in mp.limbs(carry)])

    if len(a.shape) > 2 or len(b.shape) > 2:
        return _execute_batched(plan, a, b, inner=run2d)
    return run2d(a, b)


# --------------------------------------------------------------------------
# dispatch + failover
# --------------------------------------------------------------------------


def _dispatch_once(plan: GemmPlan, a, b, alpha, beta, c, batched: bool,
                   sharded: bool, check: str):
    """Route one (validated) workload to its path; return (out, flags)."""
    if batched and not sharded:
        return _execute_batched_jit(a, b, alpha, beta, c, plan=plan,
                                    check=check)
    if sharded:
        # _execute_sharded routes batched operands through vmap-outside-
        # shard_map itself, so batched + sharded is one engine call
        out = _execute_sharded(plan, a, b)
        if alpha is not None or c is not None:
            out = _apply_epilogue_jit(out, alpha, beta, c)
        flags = None
        if check != "none":
            # the SUMMA runner compiles outside the plan-keyed wrappers
            # (plan hash excludes the mesh), so guarding it costs one
            # extra eager probe dispatch — accepted: multi-device calls
            # are large enough to amortize it
            flags = guard.probe(a, b, c, out, alpha, beta, plan=plan,
                                check=check)
        return out, flags
    if alpha is not None and plan.backend == "ozaki-pallas":
        # fused drain: the epilogue runs in VMEM before the C' tile drains
        if c is None:
            return _execute_fused_alpha_jit(a, b, alpha, plan=plan,
                                            check=check)
        return _execute_fused_full_jit(a, b, alpha, beta, c, plan=plan,
                                       check=check)
    return _execute_2d_jit(a, b, alpha, beta, c, plan=plan, check=check)


def _fallback_plan(plan: GemmPlan, backend: str, m: int, k: int,
                   n: int) -> GemmPlan:
    """Re-plan the same workload onto a fallback backend.

    Structural parameters (tier, platform, mesh, batch shape, check) carry
    over; backend-specific ones (blocks, slice params) re-solve for the
    new backend.  ``use_cache=False``: the failover path must not consult
    the quarantine it is itself writing, and a tuned-tile lookup is not
    worth a second cache read on an error path.
    """
    return make_plan(
        m, k, n, dtype=plan.limb_dtype, precision=plan.precision,
        backend=backend, batch_shape=plan.batch_shape,
        interpret=plan.interpret, platform=plan.platform, mesh=plan.mesh,
        shard_axis=plan.shard_axis, shard_axis_n=plan.shard_axis_n,
        k_panel=plan.k_panel, comm=plan.comm, k_stream=plan.k_stream,
        check=plan.check, use_cache=False)


def _dispatch_with_failover(plan: GemmPlan, a, b, alpha, beta, c,
                            batched: bool, sharded: bool, check: str):
    """Dispatch, retrying down the plan's fallback chain on backend failure.

    Returns ``(out, flags, used_plan)``.  Failure semantics:

      * backends with an EMPTY chain (xla, ref, unknown) dispatch bare —
        their exceptions re-raise unchanged (failover must not reword the
        engine's own diagnostics, and 'xla' failing means the problem is
        not the backend);
      * :class:`NumericalHazardError` always re-raises — it is a verdict
        about the *data*, and a fallback backend would reach the same one;
      * any other exception quarantines the failing backend (so repeat
        calls skip it at plan time), warns, and retries the next rung;
      * all rungs failing raises :class:`BackendExecutionError` carrying
        every ``(backend, error)`` attempt.

    ``REPRO_GEMM_FAILOVER=0`` disables the whole mechanism (bisection
    wants the original traceback).
    """
    chain = fallback_chain(plan.backend, plan.precision)
    if not chain or os.environ.get(_ENV_FAILOVER, "1") == "0":
        out, flags = _dispatch_once(plan, a, b, alpha, beta, c, batched,
                                    sharded, check)
        return out, flags, plan
    m, k = a.shape[-2:]
    n = b.shape[-1]
    attempts = []
    cur = plan
    for nxt in chain + (None,):
        try:
            out, flags = _dispatch_once(cur, a, b, alpha, beta, c,
                                        batched, sharded, check)
            return out, flags, cur
        except NumericalHazardError:
            raise
        except Exception as e:  # noqa: BLE001 — failover IS the handler
            attempts.append((cur.backend, repr(e)))
            plan_cache.quarantine(cur.platform, cur.backend, cur.nlimbs,
                                  reason=repr(e))
            if nxt is None:
                break
            warnings.warn(
                f"GEMM backend {cur.backend!r} failed "
                f"({type(e).__name__}: {e}); quarantined for "
                f"{plan_cache.QUARANTINE_TTL:.0f}s, failing over to "
                f"{nxt!r}", BackendFailoverWarning, stacklevel=3)
            cur = _fallback_plan(plan, nxt, m, k, n)
    raise BackendExecutionError(
        f"every backend in the fallback chain failed for this "
        f"{plan.precision} GEMM: "
        + "; ".join(f"{be}: {err}" for be, err in attempts)
        + " (REPRO_GEMM_FAILOVER=0 re-raises the first failure directly)",
        attempts=tuple(attempts))


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def execute(plan: GemmPlan, a, b, *, alpha=None, beta=None, c=None,
            check: Optional[str] = None, k_stream: Optional[int] = None):
    """Run C = alpha * (A @ B) + beta * C under a plan.

    A: (..., m, k), B: (..., k, n).  ``alpha``/``beta`` (python floats or
    tier scalars) and ``c`` are the optional Rgemm epilogue: fused into the
    kernel drain on the 2-D ``ozaki-pallas`` path, applied as an identical
    tier-arithmetic post-step everywhere else.  With no epilogue operands
    this is plain C = A @ B; with ``c`` alone, alpha and beta default to
    1.0 (C is *added*, never silently dropped).  BLAS semantics govern
    beta: ``beta == 0`` means C is **not read** (NaN/Inf in C cannot
    leak), and a nonzero beta without ``c=`` raises rather than being
    silently dropped.

    ``check`` selects the guarded-execution level (defaults to the plan's
    ``check`` field): ``"none"`` propagates hazards IEEE-style, zero
    overhead; ``"finite"`` raises a typed
    :class:`~repro.runtime.faults.NumericalHazardError` /
    :class:`~repro.runtime.faults.SliceOverflowError` naming the offending
    operand on NaN/Inf input-or-output or sliced-backend operand overflow;
    ``"full"`` additionally validates the result against an f64 shadow
    product (catches finite-but-wrong results — flipped limbs, lost SUMMA
    panels).  Guarded raising degrades to propagation under an outer jit
    (flags are tracers there); see ``gemm.guard``.

    ``k_stream`` (per-call override of the plan field) turns on host-side
    out-of-core K streaming on sharded plans: A/B feed through the SUMMA
    runner in ``k_stream``-deep K chunks while the block-sharded C'
    accumulator stays device-resident, and the result is bit-identical to
    the unstreamed call (see ``_execute_k_stream``).

    Backend compile/run failures retry down the plan's declared fallback
    chain (``ozaki-pallas → ozaki → xla``), quarantining each failed
    backend in the plan cache; exhaustion raises
    :class:`~repro.runtime.faults.BackendExecutionError`.
    """
    check = guard.resolve_check(check, plan)
    if k_stream is not None:
        if plan.mesh is None:
            raise ValueError(
                "k_stream= requires a sharded plan (mesh=): the out-of-"
                "core K stream feeds chunks through the SUMMA runner")
        if k_stream <= 0:
            raise ValueError(f"k_stream must be positive, got {k_stream}")
        plan = plan.with_(k_stream=k_stream)
    prec = mp.precision_of(a)
    if mp.precision_of(b) != prec:
        raise TypeError(f"operand tiers differ: {mp.precision_of(a)} vs "
                        f"{mp.precision_of(b)}")
    if prec != plan.precision:
        raise ValueError(
            f"plan is for precision={plan.precision!r} but operands are "
            f"{prec!r}; rebuild with make_plan(..., precision={prec!r}) "
            f"(engine.matmul infers this from the operand type)")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
    limb_dtype = mp.limbs(a)[0].dtype
    if beta is not None and c is None:
        # BLAS Rgemm: beta scales C, so beta without a C operand is
        # meaningful only when it is zero ("C is not read").  Anything
        # else would be silently dropped — raise instead, mirroring the
        # alpha/c defaulting rules (c alone => alpha = beta = 1, never a
        # dropped operand)
        if not _static_zero(beta):
            raise ValueError(
                f"beta={beta!r} was passed without c=; beta scales C, so "
                f"a nonzero (or traced) beta without a C operand would be "
                f"silently dropped — pass c=, or beta=0 (BLAS: C not read)")
        beta = None
    if c is not None and _static_zero(beta):
        # BLAS: beta == 0 means C is NOT read — drop the term outright so
        # a NaN/Inf in C cannot leak through 0 * C (traced zero betas get
        # the same guarantee from the where-guard in _apply_epilogue /
        # the fused kernel drain)
        c = beta = None
    if c is not None and alpha is None:
        alpha = 1.0
    if alpha is not None:
        alpha = _as_scalar(alpha, prec, limb_dtype)
    if c is not None:
        beta = _as_scalar(1.0 if beta is None else beta, prec, limb_dtype)
        if mp.precision_of(c) != prec:
            raise TypeError(f"C tier {mp.precision_of(c)} != operand "
                            f"tier {prec}")
    batched = len(a.shape) > 2 or len(b.shape) > 2
    # either axis suffices: a 1-axis mesh claimed entirely by an explicit
    # shard_axis_n= is pure column sharding (shard_axis stays None), which
    # the SUMMA loop handles — it must not silently run unsharded
    sharded = plan.mesh is not None and (
        plan.shard_axis is not None or plan.shard_axis_n is not None)
    if batched and plan.batch == "none":
        raise ValueError(
            "plan was made for 2-D operands but inputs have batch dims; "
            "rebuild with batch_shape= (engine.matmul does this)")
    if _faults.active():
        # chaos hooks run EAGERLY, outside the plan-keyed jit wrappers —
        # corrupting inside a traced body would cache the corrupted graph
        # under the plan key and leak the fault past its FaultPlan
        a = _faults.corrupt("gemm.a", a)
        b = _faults.corrupt("gemm.b", b)
        if c is not None:
            c = _faults.corrupt("gemm.c", c)
    out, flags, used = _dispatch_with_failover(
        plan, a, b, alpha, beta, c, batched, sharded, check)
    if _faults.active():
        out2 = _faults.corrupt("gemm.out", out)
        if out2 is not out:
            # the in-graph flags saw the clean product; re-probe the
            # corrupted one eagerly so the guard judges what the caller
            # will actually receive
            out = out2
            if check != "none":
                flags = guard.probe(a, b, c, out, alpha, beta, plan=used,
                                    check=check)
    shapes = {"A": tuple(a.shape), "B": tuple(b.shape),
              "output": tuple(out.shape)}
    if c is not None:
        shapes["C"] = tuple(c.shape)
    guard.raise_on_flags(flags, used, check, shapes)
    return out


def matmul(a, b, *, plan: Optional[GemmPlan] = None, alpha=None, beta=None,
           c=None, **overrides):
    """Plan-and-execute convenience: the repo-wide GEMM entry point.

    The precision tier is inferred from the operand type (``dd.DD`` ->
    ``"dd"``, ``td.TD`` -> ``"td"``, ``qd.QD`` -> ``"qd"``) unless
    overridden.  ``overrides`` are
    forwarded to ``make_plan`` (backend=, bm/bn/bk=, mesh=, shard_axis=,
    ...); pass a prebuilt ``plan`` to skip planning.  The two are exclusive
    — a plan already fixes every decision, so overrides alongside it would
    be silently dead.  ``alpha``/``beta``/``c`` are the optional Rgemm
    epilogue operands (see ``execute``); ``core.blas.rgemm`` routes its
    epilogue through here so fusion-capable backends can claim it.
    """
    if plan is not None and overrides:
        raise ValueError(
            f"pass either plan= or planner overrides, not both "
            f"(got overrides {sorted(overrides)} with an explicit plan; "
            f"use plan.with_(...) to modify it)")
    if plan is None:
        m, k = a.shape[-2:]
        k2, n = b.shape[-2:]
        if k != k2:
            raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
        batch_shape = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        overrides.setdefault("precision", mp.precision_of(a))
        plan = make_plan(m, k, n, dtype=a.limbs()[0].dtype,
                         batch_shape=batch_shape, **overrides)
    return execute(plan, a, b, alpha=alpha, beta=beta, c=c)
