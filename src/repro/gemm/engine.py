"""Execution engine: one dispatcher for every extended-precision GEMM.

``execute(plan, a, b)`` routes a planned workload to its backend kernel and
adds the two capabilities the per-call dispatch never had:

  * **batched GEMM** — leading batch dimensions on either operand are
    flattened and vmapped over the planned 2-D kernel, so SDP's
    per-constraint ``X @ (A_j Z^-1)`` stacks run as one call instead of a
    Python loop over constraints;
  * **sharded GEMM** — with a mesh in the plan, the M dimension is
    row-sharded via ``shard_map``: each device computes its row panel
    against a replicated B and the output *stays* row-sharded
    (``P(axis, None)``) — no all-gather on the result, matching the paper's
    Feed/Drain streaming where C' tiles drain independently.

The backend kernels themselves are unchanged: the Pallas systolic tile
(``kernels/ddgemm.py``), the Ozaki slicing path (``core/ozaki.py``), the
blocked-XLA fallback and the O(m*k*n) oracle.  Padding to block multiples is
exact in DD arithmetic (zeros carry no rounding), so the engine owns all
pad/clamp/slice logic that used to live in ``kernels/ops.py``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dd
from .plan import GemmPlan, make_plan, round_up as _round_up

__all__ = ["execute", "matmul"]


def _pad_to(x, rows, cols):
    r, c = x.shape[-2:]
    if r == rows and c == cols:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, rows - r), (0, cols - c)]
    return jnp.pad(x, pad)


# --------------------------------------------------------------------------
# 2-D backend dispatch
# --------------------------------------------------------------------------


def _execute_pallas(plan: GemmPlan, a: dd.DD, b: dd.DD) -> dd.DD:
    from repro.kernels.ddgemm import ddgemm_kernel_call

    from .plan import _clamp_blocks

    m, k = a.shape
    _, n = b.shape
    # re-clamp against the *actual* shapes: sharded execution hands each
    # device a row panel smaller than the global problem the plan saw
    blk = _clamp_blocks(m, k, n, plan.blocks)
    bm, bn, bk = blk["bm"], blk["bn"], blk["bk"]
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_hi, a_lo = _pad_to(a.hi, mp, kp), _pad_to(a.lo, mp, kp)
    b_hi, b_lo = _pad_to(b.hi, kp, np_), _pad_to(b.lo, kp, np_)
    o_hi, o_lo = ddgemm_kernel_call(
        a_hi, a_lo, b_hi, b_lo, bm=bm, bn=bn, bk=bk, interpret=plan.interpret)
    return dd.DD(o_hi[:m, :n], o_lo[:m, :n])


def _execute_2d(plan: GemmPlan, a: dd.DD, b: dd.DD) -> dd.DD:
    if plan.backend == "pallas":
        return _execute_pallas(plan, a, b)
    if plan.backend == "ozaki":
        from repro.core.ozaki import ozaki_gemm

        kw = {}
        if plan.slice_dtype:
            kw["slice_dtype"] = jnp.dtype(plan.slice_dtype)
        if plan.acc_dtype:
            kw["acc_dtype"] = jnp.dtype(plan.acc_dtype)
        if plan.n_slices is not None:
            kw["n_slices"] = plan.n_slices
        if plan.target_bits is not None:
            kw["target_bits"] = plan.target_bits
        if plan.full is not None:
            kw["full"] = plan.full
        return ozaki_gemm(a, b, **kw)
    if plan.backend == "xla":
        from repro.kernels.ops import matmul_dd_xla

        return matmul_dd_xla(a, b, chunk=plan.bk)
    if plan.backend == "ref":
        from repro.kernels.ref import ddgemm_ref

        return ddgemm_ref(a, b)
    raise ValueError(f"unknown backend in plan: {plan.backend!r}")


# --------------------------------------------------------------------------
# batched execution (leading batch dims -> vmap over the planned kernel)
# --------------------------------------------------------------------------


def _execute_batched(plan: GemmPlan, a: dd.DD, b: dd.DD) -> dd.DD:
    a_batch = a.hi.shape[:-2]
    b_batch = b.hi.shape[:-2]
    batch = jnp.broadcast_shapes(a_batch, b_batch)
    nb = math.prod(batch)

    def flat(x: dd.DD, had_batch) -> dd.DD:
        if not had_batch:
            return x
        tgt = batch + x.hi.shape[-2:]
        hi = jnp.broadcast_to(x.hi, tgt).reshape((nb,) + x.hi.shape[-2:])
        lo = jnp.broadcast_to(x.lo, tgt).reshape((nb,) + x.lo.shape[-2:])
        return dd.DD(hi, lo)

    af = flat(a, bool(a_batch))
    bf = flat(b, bool(b_batch))
    fn = jax.vmap(lambda x, y: _execute_2d(plan, x, y),
                  in_axes=(0 if a_batch else None, 0 if b_batch else None))
    out = fn(af, bf)
    m, n = out.hi.shape[-2:]
    return dd.DD(out.hi.reshape(batch + (m, n)),
                 out.lo.reshape(batch + (m, n)))


# --------------------------------------------------------------------------
# sharded execution (M-dim row sharding, all-gather-free output)
# --------------------------------------------------------------------------


def _execute_sharded(plan: GemmPlan, a: dd.DD, b: dd.DD) -> dd.DD:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, axis = plan.mesh, plan.shard_axis
    nshards = mesh.shape[axis]
    m, k = a.shape
    _, n = b.shape
    mp = _round_up(m, nshards)
    a_hi, a_lo = _pad_to(a.hi, mp, k), _pad_to(a.lo, mp, k)

    def local(ah, al, bh, bl):
        out = _execute_2d(plan, dd.DD(ah, al), dd.DD(bh, bl))
        return out.hi, out.lo

    row = P(axis, None)
    rep = P(None, None)
    o_hi, o_lo = shard_map(
        local, mesh=mesh,
        in_specs=(row, row, rep, rep),
        # the output stays row-sharded: each device drains its own C' panel,
        # no all-gather — consumers slice or keep computing shard-local
        out_specs=(row, row),
        check_rep=False,
    )(a_hi, a_lo, b.hi, b.lo)
    if mp == m:
        return dd.DD(o_hi, o_lo)  # keeps the row-sharded layout
    return dd.DD(o_hi[:m], o_lo[:m])


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def execute(plan: GemmPlan, a: dd.DD, b: dd.DD) -> dd.DD:
    """Run C = A @ B under a plan.  A: (..., m, k), B: (..., k, n)."""
    if a.hi.shape[-1] != b.hi.shape[-2]:
        raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
    batched = a.hi.ndim > 2 or b.hi.ndim > 2
    if batched:
        if plan.mesh is not None:
            raise NotImplementedError("batched + sharded GEMM in one call")
        if plan.batch == "none":
            raise ValueError(
                "plan was made for 2-D operands but inputs have batch dims; "
                "rebuild with batch_shape= (engine.matmul does this)")
        return _execute_batched(plan, a, b)
    if plan.mesh is not None and plan.shard_axis is not None:
        return _execute_sharded(plan, a, b)
    return _execute_2d(plan, a, b)


def matmul(a: dd.DD, b: dd.DD, *, plan: Optional[GemmPlan] = None,
           **overrides) -> dd.DD:
    """Plan-and-execute convenience: the repo-wide GEMM entry point.

    ``overrides`` are forwarded to ``make_plan`` (backend=, bm/bn/bk=,
    mesh=, shard_axis=, ...); pass a prebuilt ``plan`` to skip planning.
    The two are exclusive — a plan already fixes every decision, so
    overrides alongside it would be silently dead.
    """
    if plan is not None and overrides:
        raise ValueError(
            f"pass either plan= or planner overrides, not both "
            f"(got overrides {sorted(overrides)} with an explicit plan; "
            f"use plan.with_(...) to modify it)")
    if plan is None:
        m, k = a.hi.shape[-2:]
        k2, n = b.hi.shape[-2:]
        if k != k2:
            raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
        batch_shape = jnp.broadcast_shapes(a.hi.shape[:-2], b.hi.shape[:-2])
        plan = make_plan(m, k, n, dtype=a.hi.dtype,
                         batch_shape=batch_shape, **overrides)
    return execute(plan, a, b)
