"""Execution engine: one dispatcher for every extended-precision GEMM.

``execute(plan, a, b)`` routes a planned workload to its backend kernel.
Operands are multi-limb struct-of-arrays values — ``dd.DD`` for the
``precision="dd"`` tier (2 limbs, binary128 class) or ``qd.QD`` for
``precision="qd"`` (4 limbs, binary128+) — and every capability of the
engine is limb-count generic:

  * **batched GEMM** — leading batch dimensions on either operand are
    flattened and vmapped over the planned 2-D kernel, so SDP's
    per-constraint ``X @ (A_j Z^-1)`` stacks run as one call instead of a
    Python loop over constraints;
  * **sharded GEMM** — with a mesh in the plan, the M dimension is
    row-sharded via ``shard_map``: each device computes its row panel
    against a replicated B and the output *stays* row-sharded
    (``P(axis, None)``) — no all-gather on the result, matching the paper's
    Feed/Drain streaming where C' tiles drain independently.

Backend kernels per tier: the Pallas systolic tiles (``kernels/ddgemm.py``
/ ``kernels/qdgemm.py`` — same tile schedule, 2 vs 4 limb planes), the
fused Ozaki-slice Pallas kernel (``kernels/ozgemm.py`` — both tiers,
slice-pair dots on the matrix unit with in-VMEM recombination), the
blocked-XLA fallbacks, the O(m*k*n) oracles, and — dd only — the whole-K
Ozaki slicing path.  Padding to block multiples is exact in multi-limb
arithmetic (zeros carry no rounding), so the engine owns all
pad/clamp/slice logic.

The engine also owns the Rgemm **alpha/beta epilogue**: ``execute``/
``matmul`` accept optional ``alpha``/``beta``/``c`` operands.  On the
``ozaki-pallas`` 2-D path the epilogue is fused into the kernel's drain
step (the C' tile is scaled and combined before it leaves VMEM); every
other path applies the identical tier arithmetic as a post-step, so
results match cell-for-cell across backends.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mp
from .plan import GemmPlan, make_plan, round_up as _round_up

__all__ = ["execute", "matmul"]


def _pad_to(x, rows, cols):
    r, c = x.shape[-2:]
    if r == rows and c == cols:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, rows - r), (0, cols - c)]
    return jnp.pad(x, pad)


def _pad(x, rows, cols):
    return mp.map_limbs(lambda l: _pad_to(l, rows, cols), x)


# --------------------------------------------------------------------------
# 2-D backend dispatch
# --------------------------------------------------------------------------


def _execute_pallas(plan: GemmPlan, a, b):
    from .plan import _clamp_blocks

    m, k = a.shape
    _, n = b.shape
    # re-clamp against the *actual* shapes: sharded execution hands each
    # device a row panel smaller than the global problem the plan saw
    blk = _clamp_blocks(m, k, n, plan.blocks)
    bm, bn, bk = blk["bm"], blk["bn"], blk["bk"]
    mpad, npad, kpad = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_p, b_p = _pad(a, mpad, kpad), _pad(b, kpad, npad)
    if plan.precision == "qd":
        from repro.kernels.qdgemm import qdgemm_kernel_call

        out = qdgemm_kernel_call(*mp.limbs(a_p), *mp.limbs(b_p),
                                 bm=bm, bn=bn, bk=bk,
                                 interpret=plan.interpret)
    else:
        from repro.kernels.ddgemm import ddgemm_kernel_call

        out = ddgemm_kernel_call(*mp.limbs(a_p), *mp.limbs(b_p),
                                 bm=bm, bn=bn, bk=bk,
                                 interpret=plan.interpret)
    return mp.from_limbs([o[:m, :n] for o in out])


def _ozaki_pallas_params(plan: GemmPlan, bk: int):
    """(beta, n_slices, slice_dtype_name, acc_dtype_name) for a slab depth.

    The plan solved (beta, n_slices) for its own bk; a re-clamped smaller
    slab only gains exactness headroom, so the planned values stay valid.
    Hand-built plans without solved parameters get them solved here.
    """
    from repro.core import ozaki as _ozaki

    slice_dtype = jnp.dtype(plan.slice_dtype) if plan.slice_dtype \
        else jnp.float64
    acc_dtype = jnp.dtype(plan.acc_dtype) if plan.acc_dtype else jnp.float64
    beta, n_slices = plan.slice_beta, plan.n_slices
    if beta is None or n_slices is None:
        from .plan import OZAKI_TARGET_BITS

        beta, n_slices = _ozaki.slice_params(
            bk, acc_dtype, slice_dtype,
            target_bits=plan.target_bits or OZAKI_TARGET_BITS[plan.precision],
            n_slices=n_slices, beta=beta)
    return beta, n_slices, slice_dtype.name, acc_dtype.name


def _execute_ozaki_pallas(plan: GemmPlan, a, b, alpha=None, beta=None,
                          c=None):
    """The fused Ozaki-slice kernel, optionally with the in-drain epilogue."""
    from .plan import _clamp_blocks
    from repro.kernels.ozgemm import ozgemm_kernel_call

    m, k = a.shape
    _, n = b.shape
    blk = _clamp_blocks(m, k, n, plan.blocks)
    bm, bn, bk = blk["bm"], blk["bn"], blk["bk"]
    sbeta, n_slices, sdt, adt = _ozaki_pallas_params(plan, bk)
    mpad, npad, kpad = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    operands = list(mp.limbs(_pad(a, mpad, kpad)))
    operands += list(mp.limbs(_pad(b, kpad, npad)))
    epilogue = "none"
    if alpha is not None:
        epilogue = "alpha" if c is None else "full"
        operands += [l.reshape(1, 1) for l in mp.limbs(alpha)]
        if c is not None:
            operands += [l.reshape(1, 1) for l in mp.limbs(beta)]
            operands += list(mp.limbs(_pad(c, mpad, npad)))
    out = ozgemm_kernel_call(*operands, bm=bm, bn=bn, bk=bk, beta=sbeta,
                             n_slices=n_slices, slice_dtype_name=sdt,
                             acc_dtype_name=adt, epilogue=epilogue,
                             full=bool(plan.full),
                             interpret=plan.interpret)
    return mp.from_limbs([o[:m, :n] for o in out])


def _execute_2d(plan: GemmPlan, a, b):
    if plan.backend == "pallas":
        return _execute_pallas(plan, a, b)
    if plan.backend == "ozaki-pallas":
        return _execute_ozaki_pallas(plan, a, b)
    if plan.backend == "ozaki":
        if plan.precision != "dd":
            raise ValueError("ozaki backend has no qd tier (make_plan "
                             "should have rerouted or rejected this plan)")
        from repro.core.ozaki import ozaki_gemm

        kw = {}
        if plan.slice_dtype:
            kw["slice_dtype"] = jnp.dtype(plan.slice_dtype)
        if plan.acc_dtype:
            kw["acc_dtype"] = jnp.dtype(plan.acc_dtype)
        if plan.n_slices is not None:
            kw["n_slices"] = plan.n_slices
        if plan.slice_beta is not None:
            kw["beta"] = plan.slice_beta
        if plan.target_bits is not None:
            kw["target_bits"] = plan.target_bits
        if plan.full is not None:
            kw["full"] = plan.full
        return ozaki_gemm(a, b, **kw)
    if plan.backend == "xla":
        if plan.precision == "qd":
            from repro.kernels.ops import matmul_qd_xla

            return matmul_qd_xla(a, b, chunk=plan.bk)
        from repro.kernels.ops import matmul_dd_xla

        return matmul_dd_xla(a, b, chunk=plan.bk)
    if plan.backend == "ref":
        if plan.precision == "qd":
            from repro.kernels.ref import qdgemm_ref

            return qdgemm_ref(a, b)
        from repro.kernels.ref import ddgemm_ref

        return ddgemm_ref(a, b)
    raise ValueError(f"unknown backend in plan: {plan.backend!r}")


# --------------------------------------------------------------------------
# batched execution (leading batch dims -> vmap over the planned kernel)
# --------------------------------------------------------------------------


def _execute_batched(plan: GemmPlan, a, b):
    a_batch = a.shape[:-2]
    b_batch = b.shape[:-2]
    batch = jnp.broadcast_shapes(a_batch, b_batch)
    nb = math.prod(batch)

    def flat(x, had_batch):
        if not had_batch:
            return x
        tgt = batch + x.shape[-2:]
        return mp.map_limbs(
            lambda l: jnp.broadcast_to(l, tgt).reshape((nb,) + l.shape[-2:]),
            x)

    af = flat(a, bool(a_batch))
    bf = flat(b, bool(b_batch))
    # DD/QD are NamedTuple pytrees: in_axes=0 maps every limb plane
    fn = jax.vmap(lambda x, y: _execute_2d(plan, x, y),
                  in_axes=(0 if a_batch else None, 0 if b_batch else None))
    out = fn(af, bf)
    m, n = out.shape[-2:]
    return mp.map_limbs(lambda l: l.reshape(batch + (m, n)), out)


# jit wrappers keyed on the (frozen, hashable) plan: without these, every
# eager call re-traces the backend's scan/vmap/pallas graph — at the qd
# tier that retrace is thousands of ops and dominates wall time (observed
# in the SDP inner loop).  The alpha/beta/c epilogue operands ride inside
# the same jit (None is an empty pytree, so epilogue-free calls compile
# their own specialization): an eager post-step epilogue at the qd tier is
# hundreds of per-limb ops per call, which dominated the refinement
# solver's residual r = b - A x.  The mesh field is excluded from plan
# equality/hash, so only the mesh-free paths go through here; sharded
# execution compiles inside shard_map as before.


@functools.partial(jax.jit, static_argnames=("plan",))
def _execute_2d_jit(a, b, alpha, beta, c, *, plan: GemmPlan):
    return _apply_epilogue(_execute_2d(plan, a, b), alpha, beta, c)


@functools.partial(jax.jit, static_argnames=("plan",))
def _execute_batched_jit(a, b, alpha, beta, c, *, plan: GemmPlan):
    return _apply_epilogue(_execute_batched(plan, a, b), alpha, beta, c)


@functools.partial(jax.jit, static_argnames=("plan",))
def _execute_fused_alpha_jit(a, b, alpha, *, plan: GemmPlan):
    return _execute_ozaki_pallas(plan, a, b, alpha=alpha)


@functools.partial(jax.jit, static_argnames=("plan",))
def _execute_fused_full_jit(a, b, alpha, beta, c, *, plan: GemmPlan):
    return _execute_ozaki_pallas(plan, a, b, alpha=alpha, beta=beta, c=c)


# --------------------------------------------------------------------------
# alpha/beta epilogue (paper Eq. 1, host side of the Rgemm split)
# --------------------------------------------------------------------------


def _as_scalar(x, precision: str, dtype):
    """Coerce a python float / multi-limb scalar to the operands' tier."""
    try:
        return mp.promote(x, precision)
    except TypeError:
        return mp.from_float(jnp.asarray(x, dtype), precision)


def _apply_epilogue(out, alpha, beta, c):
    """out = alpha * out [+ beta * c] in the operands' own tier — the
    post-step form, numerically identical to the kernel-fused drain."""
    if alpha is not None:
        out = mp.mul(mp.broadcast_to(alpha, out.shape), out)
    if c is not None:
        out = mp.add(out, mp.mul(mp.broadcast_to(beta, c.shape), c))
    return out


# pure pytree arithmetic — jittable without the plan key, so the sharded
# path (whose shard_map compiles outside the plan-keyed wrappers because
# plan equality/hash excludes the mesh) still gets a compiled epilogue
# instead of hundreds of eager per-limb ops per call
_apply_epilogue_jit = jax.jit(_apply_epilogue)


# --------------------------------------------------------------------------
# sharded execution (M-dim row sharding, all-gather-free output)
# --------------------------------------------------------------------------


def _execute_sharded(plan: GemmPlan, a, b):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, axis = plan.mesh, plan.shard_axis
    nshards = mesh.shape[axis]
    nl = mp.nlimbs(a)
    m, k = a.shape
    mpad = _round_up(m, nshards)
    a_p = mp.map_limbs(lambda l: _pad_to(l, mpad, k), a)

    def local(*limbs):
        out = _execute_2d(plan, mp.from_limbs(limbs[:nl]),
                          mp.from_limbs(limbs[nl:]))
        return tuple(mp.limbs(out))

    row = P(axis, None)
    rep = P(None, None)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(row,) * nl + (rep,) * nl,
        # the output stays row-sharded: each device drains its own C' panel,
        # no all-gather — consumers slice or keep computing shard-local
        out_specs=(row,) * nl,
        check_rep=False,
    )(*mp.limbs(a_p), *mp.limbs(b))
    if mpad == m:
        return mp.from_limbs(out)  # keeps the row-sharded layout
    return mp.from_limbs([l[:m] for l in out])


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def execute(plan: GemmPlan, a, b, *, alpha=None, beta=None, c=None):
    """Run C = alpha * (A @ B) + beta * C under a plan.

    A: (..., m, k), B: (..., k, n).  ``alpha``/``beta`` (python floats or
    tier scalars) and ``c`` are the optional Rgemm epilogue: fused into the
    kernel drain on the 2-D ``ozaki-pallas`` path, applied as an identical
    tier-arithmetic post-step everywhere else.  With no epilogue operands
    this is plain C = A @ B; with ``c`` alone, alpha and beta default to
    1.0 (C is *added*, never silently dropped).
    """
    prec = mp.precision_of(a)
    if mp.precision_of(b) != prec:
        raise TypeError(f"operand tiers differ: {mp.precision_of(a)} vs "
                        f"{mp.precision_of(b)}")
    if prec != plan.precision:
        raise ValueError(
            f"plan is for precision={plan.precision!r} but operands are "
            f"{prec!r}; rebuild with make_plan(..., precision={prec!r}) "
            f"(engine.matmul infers this from the operand type)")
    if a.shape[-1] != b.shape[-2]:
        raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
    limb_dtype = mp.limbs(a)[0].dtype
    if c is not None and alpha is None:
        alpha = 1.0
    if alpha is not None:
        alpha = _as_scalar(alpha, prec, limb_dtype)
    if c is not None:
        beta = _as_scalar(1.0 if beta is None else beta, prec, limb_dtype)
        if mp.precision_of(c) != prec:
            raise TypeError(f"C tier {mp.precision_of(c)} != operand "
                            f"tier {prec}")
    batched = len(a.shape) > 2 or len(b.shape) > 2
    if batched:
        if plan.mesh is not None:
            raise NotImplementedError("batched + sharded GEMM in one call")
        if plan.batch == "none":
            raise ValueError(
                "plan was made for 2-D operands but inputs have batch dims; "
                "rebuild with batch_shape= (engine.matmul does this)")
        return _execute_batched_jit(a, b, alpha, beta, c, plan=plan)
    if plan.mesh is not None and plan.shard_axis is not None:
        out = _execute_sharded(plan, a, b)
        if alpha is None and c is None:
            return out
        return _apply_epilogue_jit(out, alpha, beta, c)
    if alpha is not None and plan.backend == "ozaki-pallas":
        # fused drain: the epilogue runs in VMEM before the C' tile drains
        if c is None:
            return _execute_fused_alpha_jit(a, b, alpha, plan=plan)
        return _execute_fused_full_jit(a, b, alpha, beta, c, plan=plan)
    return _execute_2d_jit(a, b, alpha, beta, c, plan=plan)


def matmul(a, b, *, plan: Optional[GemmPlan] = None, alpha=None, beta=None,
           c=None, **overrides):
    """Plan-and-execute convenience: the repo-wide GEMM entry point.

    The precision tier is inferred from the operand type (``dd.DD`` ->
    ``"dd"``, ``qd.QD`` -> ``"qd"``) unless overridden.  ``overrides`` are
    forwarded to ``make_plan`` (backend=, bm/bn/bk=, mesh=, shard_axis=,
    ...); pass a prebuilt ``plan`` to skip planning.  The two are exclusive
    — a plan already fixes every decision, so overrides alongside it would
    be silently dead.  ``alpha``/``beta``/``c`` are the optional Rgemm
    epilogue operands (see ``execute``); ``core.blas.rgemm`` routes its
    epilogue through here so fusion-capable backends can claim it.
    """
    if plan is not None and overrides:
        raise ValueError(
            f"pass either plan= or planner overrides, not both "
            f"(got overrides {sorted(overrides)} with an explicit plan; "
            f"use plan.with_(...) to modify it)")
    if plan is None:
        m, k = a.shape[-2:]
        k2, n = b.shape[-2:]
        if k != k2:
            raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
        batch_shape = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
        overrides.setdefault("precision", mp.precision_of(a))
        plan = make_plan(m, k, n, dtype=a.limbs()[0].dtype,
                         batch_shape=batch_shape, **overrides)
    return execute(plan, a, b, alpha=alpha, beta=beta, c=c)
