"""Guarded execution: numerical-hazard checks for the GEMM engine.

The engine's default contract is IEEE-style *propagation*: a NaN in an
operand flows through tier arithmetic into the product, exactly as the
paper's FPGA datapath would stream it.  That is the right default for a
kernel — and the wrong one for a serving stack, where a silent NaN in one
SDP constraint poisons a whole barrier step.  This module implements the
opt-in check ladder ``execute(..., check=...)`` / ``GemmPlan.check``:

``"none"``
    the historical contract — hazards propagate, zero overhead.

``"finite"``
    validates operands and output for NaN/Inf, and — for the Ozaki sliced
    backends — operand magnitudes against the slice-extraction anchor
    range (:class:`~repro.runtime.faults.SliceOverflowError`; overflow
    there corrupts slices *silently*, producing finite-looking garbage).
    Raises :class:`~repro.runtime.faults.NumericalHazardError` naming the
    offending operand and first bad index.

``"full"``
    everything ``"finite"`` does, plus a **shadow product**: the f64
    projection of the operands is multiplied in plain float64 and the
    guarded result's projection must agree to within the f64 error bound
    scaled by ``_SHADOW_RTOL``.  This is the only check that can see
    *finite but wrong* results — a flipped limb, a lost SUMMA panel — at
    the cost of one f64 GEMM (~1/16 the flops of a qd product, ~1/4 of
    dd).  Sub-f64 corruption (a low-limb flip) is below the shadow's
    resolution and documented as undetectable here; the refinement
    solver's residual gates own that band.

Design: flag *computation* (:func:`hazard_flags`) is pure traced jnp and
runs **inside** the engine's plan-keyed jit wrappers — one dispatch total,
which is what keeps the ``check="finite"`` overhead inside the ≤15%
acceptance budget.  Flag *interpretation* (:func:`raise_on_flags`) is
host-side and eager; under an outer ``jit`` (e.g. the refinement solver's
residual step) the flags are tracers, raising is impossible, and the check
degrades to propagation — callers that need hard guarantees run eagerly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mp
from repro.runtime.faults import NumericalHazardError, SliceOverflowError

from .plan import GemmPlan

__all__ = ["CHECKS", "resolve_check", "hazard_flags", "probe",
           "raise_on_flags", "slice_overflow_limit"]

CHECKS = ("none", "finite", "full")

# shadow-product agreement: |f64(out) - shadow| <= bound * _SHADOW_RTOL
# where bound is the elementwise f64 forward-error envelope |A||B| + |bC|.
# 2^-35 sits ~18 bits above f64's unit roundoff (the shadow's own error is
# O(k * 2^-53) * bound, k <= ~2^14 in our test envelope) and ~18 bits of
# margin below any real fault a whole-limb upset can cause (the smallest
# modelled fault flips limb 0 by one exponent bit: a relative error of
# O(1)).  False positives and false negatives both need ~2^17 of slack to
# cross it.
_SHADOW_RTOL = 2.0 ** -35


def resolve_check(check: Optional[str], plan: GemmPlan) -> str:
    """Effective check level: explicit argument > plan field > "none"."""
    c = check if check is not None else getattr(plan, "check", "none")
    if c not in CHECKS:
        raise ValueError(f"unknown check level {c!r}; one of {CHECKS}")
    return c


def slice_overflow_limit(plan: GemmPlan) -> Optional[float]:
    """Largest |entry| the Ozaki slice extraction can anchor without
    overflow, or None when the plan's backend does not slice.

    ExtractVector's anchor is ``sigma = 2^(e_mu + p - beta)`` for operand
    magnitude ``2^e_mu``, limb-significand width ``p``, and slice width
    ``beta``; sigma must stay finite, so ``e_mu <= E_max - (p - beta)``.
    One extra octave is reserved because the ``x + sigma`` sum can carry
    into ``2^(e_sigma + 1)``.
    """
    if plan.slice_beta is None:
        return None
    finfo = jnp.finfo(jnp.dtype(plan.limb_dtype))
    # e_mu_max = E_max - 1 - (p - beta); anchor ladder uses p = nmant + 1
    exp = finfo.maxexp - 2 - (finfo.nmant + 1 - plan.slice_beta)
    return float(2.0 ** exp)


def _nonfinite_flags(name: str, x, flags: dict) -> None:
    """Fold per-operand NaN/Inf counts + first-bad-flat-index into flags."""
    nan = jnp.zeros((), jnp.int64)
    inf = jnp.zeros((), jnp.int64)
    bad = None
    for l in mp.limbs(x):
        nan = nan + jnp.sum(jnp.isnan(l), dtype=jnp.int64)
        inf = inf + jnp.sum(jnp.isinf(l), dtype=jnp.int64)
        m = ~jnp.isfinite(l)
        bad = m if bad is None else (bad | m)
    flags[f"{name}_nan"] = nan
    flags[f"{name}_inf"] = inf
    # argmax of the OR'd mask = first offending entry (0 when clean; the
    # counts disambiguate).  Flat index — the host side unravels it.
    flags[f"{name}_idx"] = jnp.argmax(bad.reshape(-1))


def hazard_flags(plan: GemmPlan, a, b, c, out, alpha, beta,
                 check: str) -> Optional[dict]:
    """Traced flag computation for one guarded execution.

    Returns a dict of scalar jnp values (or None for ``check="none"``):
    per-operand ``{A,B,C,output}_nan`` / ``_inf`` counts and ``_idx`` first
    offenders; ``A_amax`` / ``B_amax`` operand magnitudes when the plan
    slices (the overflow pre-check); and for ``check="full"`` the shadow
    ``mismatch`` ratio (worst |err| / bound over the output).  Runs inside
    the engine's jit wrappers — adding it to an execution costs a few
    reductions, not a second dispatch.
    """
    if check == "none":
        return None
    flags: dict = {}
    _nonfinite_flags("A", a, flags)
    _nonfinite_flags("B", b, flags)
    if c is not None:
        _nonfinite_flags("C", c, flags)
    if slice_overflow_limit(plan) is not None:
        flags["A_amax"] = jnp.max(jnp.abs(mp.limbs(a)[0]))
        flags["B_amax"] = jnp.max(jnp.abs(mp.limbs(b)[0]))
    _nonfinite_flags("output", out, flags)
    if check == "full":
        af, bf = mp.to_float(a), mp.to_float(b)
        shadow = af @ bf
        bound = jnp.abs(af) @ jnp.abs(bf)
        if alpha is not None:
            alf = mp.to_float(alpha)
            shadow = alf * shadow
            bound = jnp.abs(alf) * bound
        if c is not None:
            bc = mp.to_float(beta) * mp.to_float(c)
            shadow = shadow + bc
            bound = bound + jnp.abs(bc)
        err = jnp.abs(mp.to_float(out) - shadow)
        # the tiny absolute floor keeps exact-zero cells (bound == 0) from
        # dividing 0/0; any fault big enough to matter clears it trivially
        ratio = err / (bound + 2.0 ** -1000)
        # a NaN/Inf anywhere makes the ratio NaN; the nonfinite flags
        # already own that case, so the mismatch verdict masks it out
        ratio = jnp.where(jnp.isfinite(ratio), ratio, 0.0)
        flags["mismatch"] = jnp.max(ratio)
        flags["mismatch_idx"] = jnp.argmax(ratio.reshape(-1))
    return flags


@functools.partial(jax.jit, static_argnames=("plan", "check"))
def probe(a, b, c, out, alpha, beta, *, plan: GemmPlan, check: str):
    """Eagerly-dispatchable :func:`hazard_flags` (sharded / post-hoc use)."""
    return hazard_flags(plan, a, b, c, out, alpha, beta, check)


def _first_index(flags: dict, name: str, shape) -> Optional[tuple]:
    idx = flags.get(f"{name}_idx")
    if idx is None or shape is None:
        return None
    try:
        return tuple(int(i) for i in np.unravel_index(int(idx), shape))
    except ValueError:
        return None


def raise_on_flags(flags: Optional[dict], plan: GemmPlan, check: str,
                   shapes: Optional[dict] = None) -> None:
    """Interpret computed flags host-side; raise the typed hazard.

    Check order is provenance order — operands before slicing before
    output before shadow — so the error names the *cause*, not the
    furthest-downstream symptom (a NaN in A also NaNs the output and the
    shadow ratio; the caller must hear "A", not "mismatch").

    No-op when any flag is still a tracer (guarded execute under an outer
    jit): raising at trace time would poison every execution sharing the
    compiled graph, so the check degrades to propagation there.
    """
    if flags is None or check == "none":
        return
    if any(isinstance(v, jax.core.Tracer) for v in flags.values()):
        return
    shapes = shapes or {}

    def hazard(operand, kind, **kw):
        nan = int(flags.get(f"{operand}_nan", 0))
        inf = int(flags.get(f"{operand}_inf", 0))
        index = _first_index(flags, operand, shapes.get(operand))
        at = f" (first at index {index})" if index is not None else ""
        raise NumericalHazardError(
            f"{kind} in {operand} during guarded "
            f"{plan.backend}/{plan.precision} GEMM: {nan} NaN / {inf} Inf "
            f"entries{at}; check={check!r} forbids propagation — sanitize "
            f"the operand or run with check='none' to propagate",
            kind=kind, operand=operand, backend=plan.backend,
            precision=plan.precision, index=index, nan_count=nan,
            inf_count=inf, **kw)

    for operand in ("A", "B", "C"):
        if f"{operand}_nan" not in flags:
            continue
        if int(flags[f"{operand}_nan"]):
            hazard(operand, "nan")
        if int(flags[f"{operand}_inf"]):
            hazard(operand, "inf")
    limit = slice_overflow_limit(plan)
    if limit is not None and "A_amax" in flags:
        for operand in ("A", "B"):
            amax = float(flags[f"{operand}_amax"])
            if amax > limit:
                raise SliceOverflowError(
                    f"|{operand}| max {amax:.3e} exceeds the Ozaki "
                    f"slice-extraction anchor range (limit {limit:.3e} for "
                    f"beta={plan.slice_beta}, {plan.limb_dtype}): the "
                    f"2^(e+p-beta) anchor overflows and corrupts every "
                    f"slice silently — scale the operand or use a "
                    f"non-sliced backend (xla, pallas)",
                    kind="overflow", operand=operand, backend=plan.backend,
                    precision=plan.precision,
                    detail=f"amax={amax!r} limit={limit!r}")
    if int(flags.get("output_nan", 0)) or int(flags.get("output_inf", 0)):
        hazard("output", "nan" if int(flags["output_nan"]) else "inf")
    mismatch = flags.get("mismatch")
    if mismatch is not None and float(mismatch) > _SHADOW_RTOL:
        index = _first_index(flags, "mismatch", shapes.get("output"))
        at = f" (worst at index {index})" if index is not None else ""
        raise NumericalHazardError(
            f"guarded {plan.backend}/{plan.precision} GEMM disagrees with "
            f"its f64 shadow product by {float(mismatch):.3e} of the error "
            f"bound{at} (threshold {_SHADOW_RTOL:.1e}): the result is "
            f"finite but wrong — suspect a corrupted limb, a lost SUMMA "
            f"panel, or a kernel defect; retry on the 'ref' backend to "
            f"bisect", kind="mismatch", operand="output",
            backend=plan.backend, precision=plan.precision, index=index,
            detail=f"ratio={float(mismatch)!r}")
