"""Planning layer: one decision point for every extended-precision GEMM.

The paper's FPGA fixes its execution configuration (PE-array shape, M_Tile,
operand format) at synthesis time; every GEMM then streams through that one
design.  ``GemmPlan`` is the runtime analogue: a frozen record of every
choice the engine needs — backend, block shapes, limb dtype, interpret mode,
batch strategy, and an optional mesh shard spec for the multi-device SUMMA
distribution —
produced once by ``make_plan`` from the problem shape and platform, then
handed to ``engine.execute``.  The shard spec is 2-D: ``shard_axis`` /
``shard_axis_n`` name the mesh axes carrying C's row / column blocks (named
through ``runtime.sharding``'s logical-axis rule tables) and ``k_panel``
fixes the depth of the A/B panels the SUMMA loop broadcasts per K-step —
the software analogue of the paper's DDR→BRAM panel streaming granularity.

Block shapes resolve in priority order: explicit overrides > tuned entries
from the on-disk cache (written by ``autotune``) > the clamped heuristic
``DEFAULT_BLOCKS`` defined below (and re-exported by ``kernels.ddgemm``
for kernel-level callers).
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

# the arithmetic layer owns the tier -> limb-count map (re-exported below
# so plan consumers need not import core); also enables x64 on import
from repro.core.mp import PRECISIONS
from repro.runtime.faults import BackendFailoverWarning

from . import cache as plan_cache

__all__ = ["GemmPlan", "make_plan", "replan_precision", "resolve_backend",
           "round_up", "BACKENDS", "PRECISIONS", "DEFAULT_BLOCKS",
           "OZAKI_TARGET_BITS", "FALLBACK_CHAINS", "fallback_chain"]

BACKENDS = ("auto", "pallas", "ozaki", "ozaki-pallas", "xla", "ref")

# guarded-execution levels (mirrored by gemm.guard.CHECKS; defined here so
# plan validation does not import the guard module, which imports us)
_CHECK_LEVELS = ("none", "finite", "full")

# declared failover order per backend, most- to least-specialized.  The
# chain ends at 'xla' (pure jnp — if that fails, the failure is in the
# operands or JAX itself, and failover would only mask it); 'ref' is an
# oracle, not a production fallback.  The engine walks this chain when a
# backend raises at compile/run time, quarantining each failed rung; the
# planner consults the same chain to skip quarantined backends at plan
# time.
FALLBACK_CHAINS = {
    "ozaki-pallas": ("ozaki", "xla"),
    "pallas": ("xla",),
    "ozaki": ("xla",),
    "xla": (),
    "ref": (),
}


def fallback_chain(backend: str, precision: str = "dd"):
    """The failover chain for a backend, tier-filtered.

    The whole-K 'ozaki' path has no qd tier, so qd plans skip that rung
    (make_plan would reject it; the engine must not fail over into a
    ValueError).
    """
    chain = FALLBACK_CHAINS.get(backend, ())
    if precision == "qd":
        chain = tuple(b for b in chain if b != "ozaki")
    return chain

# backends that decompose operands into error-free slices; their plans
# carry solved (slice_beta, n_slices) so kernels never re-derive them
_SLICED_BACKENDS = ("ozaki", "ozaki-pallas")

# default significand coverage per tier for the slicing backends: dd is
# binary128-class (the paper's format), td the 3-limb ~159-bit middle rung,
# qd the 4-limb ~212-bit tier
OZAKI_TARGET_BITS = {"dd": 107, "td": 159, "qd": 212}

# (bm, bn, bk) heuristic defaults: the "8x16 PE / M_Tile=512" analogue from
# the bench_tile sweep — VMEM cost = (bm*bk + bk*bn + 2*bm*bn) * 2 limbs * 4B.
# Owned by the plan layer (tile choice is a planning concern); the Pallas
# kernel module re-exports it so kernel-level callers keep working without
# this module importing pallas eagerly.
DEFAULT_BLOCKS = {"bm": 128, "bn": 128, "bk": 16}

_ENV_BACKEND = "REPRO_GEMM_BACKEND"
_DEFAULT_BACKEND = "ozaki"


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Everything ``engine.execute`` needs to run one GEMM workload."""

    backend: str                      # pallas | ozaki | xla | ref
    bm: int                           # pallas M-tile; also clamps batched calls
    bn: int                           # pallas N-tile
    bk: int                           # pallas K-tile / xla K-chunk
    limb_dtype: str                   # 'float64' (dd64) | 'float32' (df32)
    interpret: bool                   # pallas interpret mode (True off-TPU)
    platform: str                     # 'cpu' | 'tpu' | 'gpu'
    precision: str = "dd"             # tier: dd (2 limbs) | td (3) | qd (4)
    batch: str = "none"               # none | vmap
    batch_shape: Tuple[int, ...] = ()
    shard_axis: Optional[str] = None  # mesh axis sharding the M (row) dim
    shard_axis_n: Optional[str] = None  # mesh axis sharding the N (col) dim
    k_panel: Optional[int] = None     # SUMMA K-panel depth (default: bk)
    comm: str = "ring"                # SUMMA panel movement: ring | psum
    k_stream: Optional[int] = None    # host-side out-of-core K chunk depth
    mesh: Any = dataclasses.field(default=None, compare=False, repr=False)
    slice_dtype: Optional[str] = None  # ozaki operand slices (bf16 on TPU)
    acc_dtype: Optional[str] = None    # ozaki accumulator (f32 on TPU)
    n_slices: Optional[int] = None     # ozaki slices per operand (solved)
    slice_beta: Optional[int] = None   # ozaki bits per slice (solved)
    target_bits: Optional[int] = None  # ozaki significand coverage target
    full: Optional[bool] = None        # ozaki: keep sub-target slice products
    check: str = "none"                # guarded execution: none|finite|full
    source: str = "heuristic"          # heuristic | tuned | override

    @property
    def blocks(self) -> dict:
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk}

    @property
    def nlimbs(self) -> int:
        return PRECISIONS[self.precision]

    def with_(self, **changes) -> "GemmPlan":
        return dataclasses.replace(self, **changes)


def resolve_backend(backend: str = "auto") -> str:
    be = backend if backend != "auto" else os.environ.get(
        _ENV_BACKEND, _DEFAULT_BACKEND)
    if be not in BACKENDS or be == "auto":
        raise ValueError(f"unknown GEMM backend {be!r}; one of {BACKENDS}")
    return be


def round_up(x: int, b: int) -> int:
    return -(-x // b) * b


def _clamp_blocks(m: int, k: int, n: int, blocks: dict) -> dict:
    # tiny problems keep tiny tiles: clamp to the 8-aligned problem size so a
    # 16x16 GEMM does not pad out to a 128x128 tile.  The single clamp rule
    # for the whole package — engine/autotune import it rather than redefine.
    return {
        "bm": min(blocks["bm"], round_up(m, 8)),
        "bn": min(blocks["bn"], round_up(n, 8)),
        "bk": min(blocks["bk"], round_up(k, 8)),
    }


def make_plan(m: int, k: int, n: int, *, dtype=jnp.float64,
              precision: str = "dd",
              backend: str = "auto", batch_shape: Tuple[int, ...] = (),
              bm: Optional[int] = None, bn: Optional[int] = None,
              bk: Optional[int] = None, interpret: Optional[bool] = None,
              platform: Optional[str] = None, mesh=None,
              shard_axis: Optional[str] = None,
              shard_axis_n: Optional[str] = None,
              k_panel: Optional[int] = None,
              comm: str = "ring",
              k_stream: Optional[int] = None,
              slice_dtype=None, acc_dtype=None,
              n_slices: Optional[int] = None,
              target_bits: Optional[int] = None, full: Optional[bool] = None,
              chunk: Optional[int] = None,
              check: str = "none",
              use_cache: bool = True) -> GemmPlan:
    """Plan one GEMM workload: (batch_shape) x (m, k) @ (k, n).

    Consults the tuned-block cache for (shape-bucket, dtype, limb count,
    platform) before falling back to clamped DEFAULT_BLOCKS, so autotuned
    tiles are reused across calls and across processes — and each precision
    tier tunes its own tiles (a QD wave moves 2x the limb planes of DD).
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {sorted(PRECISIONS)}")
    if check not in _CHECK_LEVELS:
        raise ValueError(f"unknown check level {check!r}; "
                         f"one of {_CHECK_LEVELS}")
    be = resolve_backend(backend)
    if precision == "qd" and be == "ozaki":
        if backend == "ozaki":
            # explicit request: fail loudly — whole-K slicing for a 212-bit
            # target makes the slice-product sweep useless (the per-slab
            # 'ozaki-pallas' kernel is the qd slicing path)
            raise ValueError(
                "backend 'ozaki' has no qd tier (slice count explodes past "
                "the 212-bit target); use ozaki-pallas, pallas, xla, or ref")
        be = "xla"  # 'auto'/env default 'ozaki' is a dd-oriented hint
    platform = platform or jax.default_backend()
    dtype = jnp.dtype(dtype)

    # quarantine consult: a backend that recently failed at compile/run
    # time on this (platform, limb count) is benched in the plan cache —
    # re-plan onto the first healthy rung of its fallback chain instead of
    # re-paying the doomed lowering attempt at execute time.  use_cache=
    # False opts out (tests and bisection need to hit the backend anyway).
    if use_cache:
        nl = PRECISIONS[precision]
        q = plan_cache.quarantined(platform, be, nl)
        if q is not None:
            for fb in fallback_chain(be, precision):
                if plan_cache.quarantined(platform, fb, nl) is None:
                    warnings.warn(
                        f"GEMM backend {be!r} is quarantined on "
                        f"{platform!r} ({q.get('reason', '?')}); planning "
                        f"onto fallback {fb!r} (repro.gemm."
                        f"clear_quarantine() lifts the bench)",
                        BackendFailoverWarning, stacklevel=2)
                    be = fb
                    break
            # every rung benched: keep the original backend and let the
            # engine's failover loop re-attempt (and re-diagnose) live

    if interpret is None:
        interpret = platform != "tpu"
    if chunk is not None:
        bk = bk or chunk  # legacy xla-backend spelling of the K block

    if mesh is not None:
        # the dormant logical-axis rule tables name the mesh axes: "gemm_m"
        # / "gemm_n" resolve against the mesh so GEMM meshes (rows/cols)
        # and production LM meshes (data/model) both work unannotated.
        # Fully-explicit axes route through the same resolver so a typo'd
        # or duplicated axis fails HERE, not deep inside shard_map
        from repro.runtime.sharding import gemm_mesh_axes

        shard_axis, shard_axis_n = gemm_mesh_axes(
            mesh, m_axis=shard_axis, n_axis=shard_axis_n)
    if mesh is None and not (shard_axis is None and shard_axis_n is None
                             and k_panel is None and k_stream is None):
        # a shard spec without a mesh would silently run unsharded — the
        # same dropped-operand failure mode the beta-without-c rule stops
        raise ValueError(
            "shard_axis/shard_axis_n/k_panel/k_stream require mesh= "
            "(without a mesh there is nothing to shard or stream over)")
    if k_panel is not None and k_panel <= 0:
        raise ValueError(f"k_panel must be positive, got {k_panel}")
    if comm not in ("ring", "psum"):
        raise ValueError(f"unknown SUMMA comm schedule {comm!r}; "
                         f"one of ('ring', 'psum')")
    if k_stream is not None and k_stream <= 0:
        raise ValueError(f"k_stream must be positive, got {k_stream}")

    # tuned blocks are looked up for the shape a device actually runs: a
    # sharded plan's per-device SUMMA panels are the (m/Pr, k, n/Pc) local
    # problem, not the global one the caller named
    m_l, n_l = m, n
    if mesh is not None:
        if shard_axis is not None:
            m_l = -(-m // mesh.shape[shard_axis])
        if shard_axis_n is not None:
            n_l = -(-n // mesh.shape[shard_axis_n])

    source = "heuristic"
    blocks = dict(DEFAULT_BLOCKS)
    if use_cache and be in ("pallas", "xla", "ozaki-pallas") \
            and (bm, bn, bk) == (None,) * 3:
        key = plan_cache.cache_key(platform, dtype.name, m_l, k, n_l, be,
                                   nlimbs=PRECISIONS[precision],
                                   batch_shape=batch_shape)
        tuned = plan_cache.default_cache().get(key)
        # adopt only well-formed entries: the cache is a hint, and a bad
        # persistent value (hand-edit, corruption) must degrade to the
        # heuristic, not break every GEMM in this bucket forever
        if tuned and all(
                isinstance(tuned.get(x), int) and tuned[x] > 0
                for x in ("bm", "bn", "bk")):
            blocks = {x: int(tuned[x]) for x in ("bm", "bn", "bk")}
            source = "tuned"
            # tuned n_slices was measured for the DEFAULT coverage target
            # and platform slice/acc dtypes: a caller-specified target or
            # dtype override must re-solve, not adopt it (bf16 slices cap
            # beta at 8, so an f64-tuned count would under-cover by ~70
            # bits)
            if be == "ozaki-pallas" and n_slices is None and \
                    target_bits is None and \
                    slice_dtype is None and acc_dtype is None and \
                    isinstance(tuned.get("n_slices"), int) and \
                    tuned["n_slices"] > 1:
                n_slices = tuned["n_slices"]  # tuned alongside the blocks
    blocks = _clamp_blocks(m_l, k, n_l, blocks)
    if bm or bn or bk:
        source = "override"
    blocks["bm"] = bm or blocks["bm"]
    blocks["bn"] = bn or blocks["bn"]
    blocks["bk"] = bk or blocks["bk"]

    slice_beta = None
    if be in _SLICED_BACKENDS:
        from repro.core import ozaki as _ozaki

        if slice_dtype is None and acc_dtype is None:
            slice_dtype, acc_dtype = _ozaki.platform_dtypes(platform)
        target_bits = target_bits or OZAKI_TARGET_BITS[precision]
        # the fused kernel slices per K-slab (depth bk), the XLA path
        # slices the whole K — the exactness fixpoint sees that depth
        depth = blocks["bk"] if be == "ozaki-pallas" else k
        try:
            slice_beta, n_slices = _ozaki.slice_params(
                depth, acc_dtype or jnp.float64, slice_dtype,
                target_bits=target_bits, n_slices=n_slices)
        except ValueError as e:
            # K too deep for exact slicing in the accumulator dtype: the
            # plan degrades to the portable blocked-XLA backend rather
            # than crashing the caller (tested in test_ozgemm_kernel.py)
            warnings.warn(
                f"ozaki slicing infeasible for this problem ({e}); "
                f"falling back to the 'xla' backend", RuntimeWarning,
                stacklevel=2)
            be = "xla"
            slice_dtype = acc_dtype = None
            n_slices = target_bits = None
            full = None

    return GemmPlan(
        backend=be, limb_dtype=dtype.name, interpret=bool(interpret),
        platform=platform, precision=precision,
        batch="vmap" if batch_shape else "none",
        batch_shape=tuple(batch_shape), shard_axis=shard_axis,
        shard_axis_n=shard_axis_n, k_panel=k_panel, comm=comm,
        k_stream=k_stream, mesh=mesh,
        slice_dtype=jnp.dtype(slice_dtype).name if slice_dtype else None,
        acc_dtype=jnp.dtype(acc_dtype).name if acc_dtype else None,
        n_slices=n_slices, slice_beta=slice_beta,
        target_bits=target_bits, full=full, check=check,
        source=source, **blocks)


def replan_precision(plan: GemmPlan, m: int, k: int, n: int,
                     precision: str) -> GemmPlan:
    """Re-plan the same workload at another precision tier.

    The tier-escalating refinement solver climbs the ladder mid-solve
    (f64 -> dd -> td -> qd); structural choices (backend, platform, mesh, batch
    shape) carry over, but everything tier-dependent is *re-solved* rather
    than copied — block shapes consult the new limb count's tuned-cache
    rows, and the Ozaki slice parameters re-run their exactness fixpoint
    for the new target_bits (a dd-tuned n_slices would under-cover qd by
    ~100 bits).  ``plan.with_(precision=...)`` must not exist for exactly
    that reason.  The shape is an argument because a plan does not record
    it (the paper's synthesized design is shape-free; so is ours).
    """
    if plan.precision == precision:
        return plan
    backend = plan.backend
    if backend == "ozaki" and precision == "qd":
        backend = "xla"  # the whole-K slicing path has no qd tier
    return make_plan(
        m, k, n, dtype=plan.limb_dtype, precision=precision,
        backend=backend, batch_shape=plan.batch_shape,
        interpret=plan.interpret, platform=plan.platform,
        mesh=plan.mesh, shard_axis=plan.shard_axis,
        shard_axis_n=plan.shard_axis_n, k_panel=plan.k_panel,
        comm=plan.comm, k_stream=plan.k_stream,
        check=plan.check)
