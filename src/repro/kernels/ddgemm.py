"""Pallas systolic-tile kernel for double-word (binary128-class) GEMM.

FPGA -> TPU mapping (see DESIGN.md §2):

  * the `P_R x P_C` PE array  ->  the (M/bm, N/bn) Pallas grid: each grid cell
    owns one (bm, bn) output tile and its VMEM accumulator, exactly as a PE
    owns one C' element;
  * the systolic pulse (A by column / B by row each cycle)  ->  the
    *sequential* K grid dimension: at step k the cell consumes the (bm, bk)
    slab of A and (bk, bn) slab of B, performs `bk` rank-1 DD multiply-add
    waves, and keeps the running sum in VMEM scratch;
  * the `M_Tile` on-chip buffer  ->  the BlockSpec block shapes: Pallas stages
    each (bm, bk)/(bk, bn) block HBM->VMEM, which is the cache the paper adds
    in front of the Feed module.  `benchmarks/bench_tile.py` sweeps block
    shapes the way the paper sweeps M_Tile (Fig. 3).

The multiply-add inside a wave is the DD MAC from repro.core.dd: Dekker
two_prod + two-level two_sum accumulation, ~86 native flops per binary128
FMA.  Everything is f32-limb capable (`df32`) so the design lowers for real
TPUs, where Mosaic has no f64; f64 limbs (`dd64`) run on CPU/interpret for
binary128-grade validation.

The kernel is validated in interpret mode against kernels/ref.py over shape/
dtype/block sweeps (tests/test_ddgemm_kernel.py); real-TPU deployment only
changes `interpret=False`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.efts import quick_two_sum, two_prod, two_sum

from repro.gemm.plan import DEFAULT_BLOCKS  # noqa: F401  (canonical home)

__all__ = ["ddgemm_kernel_call", "DEFAULT_BLOCKS"]

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _dd_rank1_wave(acc_hi, acc_lo, a_hi, a_lo, b_hi, b_lo):
    """One systolic wave: acc += outer(a_col, b_row) in DD arithmetic.

    a_* are (bm, 1) column limbs, b_* are (1, bn) row limbs; everything
    broadcasts to the (bm, bn) tile — one vectorized PE update.
    """
    # exact product of the hi limbs + cross terms (dd.mul, broadcasting
    # (bm,1) x (1,bn) -> (bm,bn) inside the EFT)
    p, e = two_prod(a_hi, b_hi)
    e = e + (a_hi * b_lo + a_lo * b_hi)
    p, e = quick_two_sum(p, e)
    # dd.add(acc, (p, e))
    s, f = two_sum(acc_hi, p)
    t, g = two_sum(acc_lo, e)
    f = f + t
    s, f = quick_two_sum(s, f)
    f = f + g
    return quick_two_sum(s, f)


def _ddgemm_kernel(a_hi_ref, a_lo_ref, b_hi_ref, b_lo_ref, o_hi_ref, o_lo_ref,
                   acc_hi_ref, acc_lo_ref, *, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi_ref[...] = jnp.zeros_like(acc_hi_ref)
        acc_lo_ref[...] = jnp.zeros_like(acc_lo_ref)

    a_hi, a_lo = a_hi_ref[...], a_lo_ref[...]  # (bm, bk)
    b_hi, b_lo = b_hi_ref[...], b_lo_ref[...]  # (bk, bn)

    def wave(i, carry):
        acc_hi, acc_lo = carry
        ah = jax.lax.dynamic_slice_in_dim(a_hi, i, 1, axis=1)  # (bm, 1)
        al = jax.lax.dynamic_slice_in_dim(a_lo, i, 1, axis=1)
        bh = jax.lax.dynamic_slice_in_dim(b_hi, i, 1, axis=0)  # (1, bn)
        bl = jax.lax.dynamic_slice_in_dim(b_lo, i, 1, axis=0)
        return _dd_rank1_wave(acc_hi, acc_lo, ah, al, bh, bl)

    acc_hi, acc_lo = jax.lax.fori_loop(
        0, bk, wave, (acc_hi_ref[...], acc_lo_ref[...])
    )
    acc_hi_ref[...] = acc_hi
    acc_lo_ref[...] = acc_lo

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        o_hi_ref[...] = acc_hi_ref[...]
        o_lo_ref[...] = acc_lo_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def ddgemm_kernel_call(a_hi, a_lo, b_hi, b_lo, *, bm: int, bn: int, bk: int,
                       interpret: bool = True):
    """Raw kernel invocation. Shapes must be multiples of the block shape.

    Use repro.kernels.ops.ddgemm for the padded/public entry point.
    """
    m, k = a_hi.shape
    k2, n = b_hi.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n), (bm, bn, bk))
    dtype = a_hi.dtype
    grid = (m // bm, n // bn, k // bk)
    out_shape = [
        jax.ShapeDtypeStruct((m, n), dtype),
        jax.ShapeDtypeStruct((m, n), dtype),
    ]
    kern = functools.partial(_ddgemm_kernel, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bm, bn), dtype),
            pltpu.VMEM((bm, bn), dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)
