"""Pallas systolic-tile kernel for double-word (binary128-class) GEMM.

Thin 2-plane binding of the count-generic systolic kernel
(``kernels/mlgemm.py``), kept as a named entry point for the dd tier.
The FPGA -> TPU mapping (PE array -> grid, systolic pulse -> sequential K
dimension, M_Tile buffer -> BlockSpec staging) is documented there and in
DESIGN.md §2; ``benchmarks/bench_tile.py`` sweeps block shapes the way the
paper sweeps M_Tile (Fig. 3).

The multiply-add inside a wave resolves (via ``core.mp``) to the DD MAC
from repro.core.dd: Dekker two_prod + two-level two_sum accumulation, ~86
native flops per binary128 FMA.  Everything is f32-limb capable (`df32`)
so the design lowers for real TPUs, where Mosaic has no f64; f64 limbs
(`dd64`) run on CPU/interpret for binary128-grade validation.

The kernel is validated in interpret mode against kernels/ref.py over
shape/dtype/block sweeps (tests/test_ddgemm_kernel.py); real-TPU
deployment only changes `interpret=False`.
"""

from __future__ import annotations

from repro.gemm.plan import DEFAULT_BLOCKS  # noqa: F401  (canonical home)

from .mlgemm import mlgemm_kernel_call

__all__ = ["ddgemm_kernel_call", "DEFAULT_BLOCKS"]


def ddgemm_kernel_call(a_hi, a_lo, b_hi, b_lo, *, bm: int, bn: int, bk: int,
                       interpret: bool = True):
    """Raw kernel invocation. Shapes must be multiples of the block shape.

    Use repro.kernels.ops.ddgemm for the padded/public entry point.
    """
    return mlgemm_kernel_call(a_hi, a_lo, b_hi, b_lo,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
