"""Count-generic Pallas systolic-tile kernel for multi-limb GEMM.

One kernel for every rung of the precision ladder: the limb count is a
parameter, not a code path.  FPGA -> TPU mapping (see DESIGN.md §2):

  * the `P_R x P_C` PE array  ->  the (M/bm, N/bn) Pallas grid: each grid
    cell owns one (bm, bn) output tile and its VMEM accumulator planes,
    exactly as a PE owns one C' element;
  * the systolic pulse (A by column / B by row each cycle)  ->  the
    *sequential* K grid dimension: at step k the cell consumes the
    (bm, bk) slab of A and (bk, bn) slab of B — ``nlimbs`` planes each —
    performs `bk` rank-1 multi-limb multiply-add waves, and keeps the
    running sum in ``nlimbs`` VMEM scratch planes;
  * the `M_Tile` on-chip buffer  ->  the BlockSpec block shapes: Pallas
    stages each block HBM->VMEM, the cache the paper adds in front of the
    Feed module.

The multiply-add inside a wave is the tier's FMA resolved through
``repro.core.mp`` from the plane count — dd's specialized Dekker/Li EFT
chain at 2 planes, the generic exact-product + branch-free-renormalize
recipe at 3 (td) and 4 (qd).  This is the runtime analogue of the
run-time-reconfigurable multi-precision FPGA IP cores: the architecture is
fixed, the digit count is a dispatch-time knob, and per-wave cost scales
with the limb count the plan layer's ``precision`` axis exposes.  The
autotune cache keys on limb count so every tier tunes independently.

``kernels/ddgemm.py`` and ``kernels/qdgemm.py`` remain as thin 2-/4-plane
bindings.  Validated in interpret mode against ``kernels/ref`` by the
cross-backend conformance matrix (tests/test_conformance.py) at every
count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mp

__all__ = ["mlgemm_kernel_call"]

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _mlgemm_kernel(*refs, bk: int, nlimbs: int):
    # refs: nlimbs A-limb refs, nlimbs B-limb refs, nlimbs out refs,
    # nlimbs accumulator scratch planes
    a_refs, b_refs = refs[:nlimbs], refs[nlimbs:2 * nlimbs]
    o_refs = refs[2 * nlimbs:3 * nlimbs]
    acc_refs = refs[3 * nlimbs:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        for r in acc_refs:
            r[...] = jnp.zeros_like(r)

    a = [r[...] for r in a_refs]  # (bm, bk) x nlimbs
    b = [r[...] for r in b_refs]  # (bk, bn) x nlimbs

    def wave(i, carry):
        # one systolic wave: acc += outer(a_col, b_row) in tier arithmetic;
        # (bm, 1) x (1, bn) broadcasts through the EFT chains to the tile
        a_col = mp.from_limbs(
            [jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1) for x in a])
        b_row = mp.from_limbs(
            [jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0) for x in b])
        out = mp.fma(mp.from_limbs(list(carry)), a_col, b_row)
        return tuple(mp.limbs(out))

    acc = jax.lax.fori_loop(0, bk, wave, tuple(r[...] for r in acc_refs))
    for r, v in zip(acc_refs, acc):
        r[...] = v

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        for o, r in zip(o_refs, acc_refs):
            o[...] = r[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def mlgemm_kernel_call(*limbs, bm: int, bn: int, bk: int,
                       interpret: bool = True):
    """Raw kernel invocation on nlimbs A limbs + nlimbs B limbs.

    The limb count is inferred from the argument count (``len(limbs) // 2``)
    and must name a registered tier; shapes must be block multiples.  Use
    the engine (``repro.gemm.execute``) for the padded/public entry point.
    """
    assert len(limbs) % 2 == 0, len(limbs)
    nlimbs = len(limbs) // 2
    mp.precision_for_count(nlimbs)  # raises on an unregistered count
    a_limbs, b_limbs = limbs[:nlimbs], limbs[nlimbs:]
    m, k = a_limbs[0].shape
    k2, n = b_limbs[0].shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n), (bm, bn, bk))
    dtype = a_limbs[0].dtype
    grid = (m // bm, n // bn, k // bk)
    out_shape = [jax.ShapeDtypeStruct((m, n), dtype)] * nlimbs
    kern = functools.partial(_mlgemm_kernel, bk=bk, nlimbs=nlimbs)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=(
            [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))] * nlimbs
            + [pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))] * nlimbs
        ),
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))] * nlimbs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), dtype)] * nlimbs,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*limbs)
