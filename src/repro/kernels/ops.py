"""Public jit'd entry points for the extended-precision GEMM kernels.

``ddgemm`` is now a thin shim over the unified execution engine
(``repro.gemm``), which owns the zero-padding to block multiples (zeros are
exact in DD arithmetic, so padding never changes the result), block-shape
clamping, and tuned-tile lookup that used to live here.  ``interpret=None``
auto-selects interpret mode off-TPU so the same call site deploys unchanged
on hardware.  ``matmul_dd_xla`` remains the blocked-XLA backend
implementation the engine dispatches to.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dd, qd
from repro.gemm.plan import round_up as _round_up
from .ddgemm import DEFAULT_BLOCKS  # noqa: F401  (re-export for tuners)

__all__ = ["ddgemm", "matmul_dd_xla", "matmul_qd_xla"]


def _pad_to(x, rows, cols):
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def ddgemm(a: dd.DD, b: dd.DD, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None, interpret: bool | None = None) -> dd.DD:
    """C = A @ B in double-word arithmetic via the Pallas systolic-tile kernel."""
    from repro import gemm as engine

    return engine.matmul(a, b, backend="pallas", bm=bm, bn=bn, bk=bk,
                         interpret=interpret)


def matmul_dd_xla(a: dd.DD, b: dd.DD, *, chunk: int = 16) -> dd.DD:
    """Blocked XLA (non-Pallas) DD matmul — the 'host fallback' backend.

    Streams K in chunks; each chunk materializes exact (m, chunk, n) DD
    products and reduces them with the compensated halving tree.  Used for
    CPU-side benchmarking at sizes where the O(m*k*n) oracle is infeasible.
    """
    m, k = a.shape
    _, n = b.shape
    kp = _round_up(k, chunk)
    a = dd.DD(_pad_to(a.hi, m, kp), _pad_to(a.lo, m, kp))
    b = dd.DD(_pad_to(b.hi, kp, n), _pad_to(b.lo, kp, n))
    nchunks = kp // chunk

    def body(acc, idx):
        a_blk = dd.DD(
            jax.lax.dynamic_slice_in_dim(a.hi, idx * chunk, chunk, 1),
            jax.lax.dynamic_slice_in_dim(a.lo, idx * chunk, chunk, 1),
        )
        b_blk = dd.DD(
            jax.lax.dynamic_slice_in_dim(b.hi, idx * chunk, chunk, 0),
            jax.lax.dynamic_slice_in_dim(b.lo, idx * chunk, chunk, 0),
        )
        prods = dd.mul(
            dd.DD(a_blk.hi[:, :, None], a_blk.lo[:, :, None]),
            dd.DD(b_blk.hi[None, :, :], b_blk.lo[None, :, :]),
        )
        part = dd.sum_(prods, axis=1)
        acc = dd.add(acc, part)
        return acc, None

    init = dd.zeros((m, n), dtype=a.hi.dtype)
    acc, _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return acc


def matmul_qd_xla(a: qd.QD, b: qd.QD, *, chunk: int = 16) -> qd.QD:
    """Blocked XLA (non-Pallas) QD matmul — the quad-limb 'host fallback'.

    The same K-streaming structure as ``matmul_dd_xla`` but every chunk's
    (m, chunk, n) partial products and the running accumulator are 4-limb
    expansions built from ``core.qd``'s exact-product + renormalize FMA.
    """
    m, k = a.shape
    _, n = b.shape
    kp = _round_up(k, chunk)
    a = qd.QD(*[_pad_to(l, m, kp) for l in a.limbs()])
    b = qd.QD(*[_pad_to(l, kp, n) for l in b.limbs()])
    nchunks = kp // chunk

    def body(acc, idx):
        a_blk = qd.QD(*[
            jax.lax.dynamic_slice_in_dim(l, idx * chunk, chunk, 1)
            for l in a.limbs()])
        b_blk = qd.QD(*[
            jax.lax.dynamic_slice_in_dim(l, idx * chunk, chunk, 0)
            for l in b.limbs()])
        prods = qd.mul(
            qd.QD(*[l[:, :, None] for l in a_blk.limbs()]),
            qd.QD(*[l[None, :, :] for l in b_blk.limbs()]),
        )
        part = qd.sum_(prods, axis=1)
        return qd.add(acc, part), None

    init = qd.zeros((m, n), dtype=a.x0.dtype)
    acc, _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return acc
