"""Public jit'd entry points for the extended-precision GEMM kernels.

``ddgemm`` handles arbitrary (m, k) x (k, n) shapes by zero-padding to block
multiples (zeros are exact in DD arithmetic, so padding never changes the
result), then calls the Pallas kernel.  ``interpret=None`` auto-selects
interpret mode off-TPU so the same call site deploys unchanged on hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dd
from .ddgemm import DEFAULT_BLOCKS, ddgemm_kernel_call

__all__ = ["ddgemm", "matmul_dd_xla"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, rows, cols):
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


def ddgemm(a: dd.DD, b: dd.DD, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None, interpret: bool | None = None) -> dd.DD:
    """C = A @ B in double-word arithmetic via the Pallas systolic-tile kernel."""
    bm = bm or DEFAULT_BLOCKS["bm"]
    bn = bn or DEFAULT_BLOCKS["bn"]
    bk = bk or DEFAULT_BLOCKS["bk"]
    if interpret is None:
        interpret = not _on_tpu()
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} x {b.shape}")
    # clamp blocks to (padded) problem size so tiny problems stay tiny
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    a_hi, a_lo = _pad_to(a.hi, mp, kp), _pad_to(a.lo, mp, kp)
    b_hi, b_lo = _pad_to(b.hi, kp, np_), _pad_to(b.lo, kp, np_)
    o_hi, o_lo = ddgemm_kernel_call(
        a_hi, a_lo, b_hi, b_lo, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    return dd.DD(o_hi[:m, :n], o_lo[:m, :n])


def matmul_dd_xla(a: dd.DD, b: dd.DD, *, chunk: int = 16) -> dd.DD:
    """Blocked XLA (non-Pallas) DD matmul — the 'host fallback' backend.

    Streams K in chunks; each chunk materializes exact (m, chunk, n) DD
    products and reduces them with the compensated halving tree.  Used for
    CPU-side benchmarking at sizes where the O(m*k*n) oracle is infeasible.
    """
    m, k = a.shape
    _, n = b.shape
    kp = _round_up(k, chunk)
    a = dd.DD(_pad_to(a.hi, m, kp), _pad_to(a.lo, m, kp))
    b = dd.DD(_pad_to(b.hi, kp, n), _pad_to(b.lo, kp, n))
    nchunks = kp // chunk

    def body(acc, idx):
        a_blk = dd.DD(
            jax.lax.dynamic_slice_in_dim(a.hi, idx * chunk, chunk, 1),
            jax.lax.dynamic_slice_in_dim(a.lo, idx * chunk, chunk, 1),
        )
        b_blk = dd.DD(
            jax.lax.dynamic_slice_in_dim(b.hi, idx * chunk, chunk, 0),
            jax.lax.dynamic_slice_in_dim(b.lo, idx * chunk, chunk, 0),
        )
        prods = dd.mul(
            dd.DD(a_blk.hi[:, :, None], a_blk.lo[:, :, None]),
            dd.DD(b_blk.hi[None, :, :], b_blk.lo[None, :, :]),
        )
        part = dd.sum_(prods, axis=1)
        acc = dd.add(acc, part)
        return acc, None

    init = dd.zeros((m, n), dtype=a.hi.dtype)
    acc, _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return acc
