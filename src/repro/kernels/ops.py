"""Public jit'd entry points for the extended-precision GEMM kernels.

``ddgemm`` is now a thin shim over the unified execution engine
(``repro.gemm``), which owns the zero-padding to block multiples (zeros are
exact in multi-limb arithmetic, so padding never changes the result),
block-shape clamping, and tuned-tile lookup that used to live here.
``interpret=None`` auto-selects interpret mode off-TPU so the same call
site deploys unchanged on hardware.  ``matmul_ml_xla`` is the blocked-XLA
backend implementation the engine dispatches to — count-generic over
``core.mp``, with ``matmul_dd_xla``/``matmul_qd_xla`` kept as named tier
bindings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dd, mp, qd
from repro.gemm.plan import round_up as _round_up
from .ddgemm import DEFAULT_BLOCKS  # noqa: F401  (re-export for tuners)

__all__ = ["ddgemm", "matmul_ml_xla", "matmul_dd_xla", "matmul_qd_xla"]


def _pad_to(x, rows, cols):
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def ddgemm(a: dd.DD, b: dd.DD, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None, interpret: bool | None = None) -> dd.DD:
    """C = A @ B in double-word arithmetic via the Pallas systolic-tile kernel."""
    from repro import gemm as engine

    return engine.matmul(a, b, backend="pallas", bm=bm, bn=bn, bk=bk,
                         interpret=interpret)


def matmul_ml_xla(a, b, *, chunk: int = 16):
    """Blocked XLA (non-Pallas) multi-limb matmul — the 'host fallback'.

    Streams K in chunks; each chunk materializes exact (m, chunk, n) tier
    products and reduces them with the compensated halving tree, at
    whatever limb count the operands carry.  Used for CPU-side
    benchmarking at sizes where the O(m*k*n) oracle is infeasible.
    """
    m, k = a.shape
    _, n = b.shape
    kp = _round_up(k, chunk)
    a = mp.from_limbs([_pad_to(l, m, kp) for l in mp.limbs(a)])
    b = mp.from_limbs([_pad_to(l, kp, n) for l in mp.limbs(b)])
    nchunks = kp // chunk

    def body(acc, idx):
        a_blk = mp.from_limbs([
            jax.lax.dynamic_slice_in_dim(l, idx * chunk, chunk, 1)
            for l in mp.limbs(a)])
        b_blk = mp.from_limbs([
            jax.lax.dynamic_slice_in_dim(l, idx * chunk, chunk, 0)
            for l in mp.limbs(b)])
        prods = mp.mul(
            mp.map_limbs(lambda l: l[:, :, None], a_blk),
            mp.map_limbs(lambda l: l[None, :, :], b_blk),
        )
        part = mp.sum_(prods, axis=1)
        return mp.add(acc, part), None

    init = mp.zeros((m, n), mp.precision_of(a), dtype=mp.limbs(a)[0].dtype)
    acc, _ = jax.lax.scan(body, init, jnp.arange(nchunks))
    return acc


def matmul_dd_xla(a: dd.DD, b: dd.DD, *, chunk: int = 16) -> dd.DD:
    """Blocked XLA DD matmul — the 2-limb binding of ``matmul_ml_xla``."""
    return matmul_ml_xla(a, b, chunk=chunk)


def matmul_qd_xla(a: qd.QD, b: qd.QD, *, chunk: int = 16) -> qd.QD:
    """Blocked XLA QD matmul — the 4-limb binding of ``matmul_ml_xla``."""
    return matmul_ml_xla(a, b, chunk=chunk)
