"""Pallas fused Ozaki-slice GEMM kernel — MXU-resident slicing + recombination.

The third kernel family, and the one that actually maps the paper's wide
multiplier onto the TPU's matrix unit (DESIGN.md §9).  Where
``kernels/ddgemm.py`` / ``kernels/qdgemm.py`` spend the K loop on ``bk``
scalar rank-1 EFT waves on the VPU, each grid cell here:

  1. **slices** its (bm, bk) A-slab and (bk, bn) B-slab into error-free
     Rump splits on a per-row/col power-of-two grid ladder
     (``core.ozaki._extract_slices`` — the same extraction the XLA Ozaki
     backend uses, running on VMEM-resident tiles);
  2. runs the triangular set of slice-pair products as block ``jnp.dot``s
     in the accumulator dtype — bf16 x bf16 -> f32 on the MXU on TPU, f64
     on CPU/interpret — summing each diagonal (equal s + t) natively,
     exact by the ``slice_params`` headroom;
  3. **recombines diagonals into the multi-limb (dd/td/qd) accumulator
     inside VMEM scratch**, one fold per diagonal, so recombination
     traffic never round-trips HBM;
  4. at the drain step optionally applies the Rgemm **alpha/beta epilogue**
     in tier arithmetic before the C' tile leaves VMEM (``epilogue=``:
     ``"none"`` | ``"alpha"`` | ``"full"``).

Because slices are taken per K-slab (depth ``bk``, not the full K), the
exactness condition 2*beta + log2(bk * n_slices) <= p_acc leaves far more
bits per slice than whole-K slicing — the plan layer solves (beta,
n_slices) for the slab depth and threads them here as static parameters.

Validated in interpret mode by the cross-backend conformance matrix
(tests/test_conformance.py) at every tier and by tests/test_ozgemm_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dd, mp
from repro.core.ozaki import _diagonal_pairs, _extract_slices, \
    _normalize_slices

__all__ = ["ozgemm_kernel_call", "EPILOGUES"]

EPILOGUES = ("none", "alpha", "full")

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _fold_diagonal(acc, prod):
    """acc += prod (one diagonal's native-dtype sum) in acc's own tier.

    ``prod`` may be wider than the limb dtype (f64 diagonal sums into an
    f32-limb accumulator): the excess is split off exactly into a second
    limb before the tier add, so nothing is lost to the narrowing cast.
    """
    limb_dtype = acc.limbs()[0].dtype
    k = len(acc.limbs())
    if prod.dtype == limb_dtype:
        if isinstance(acc, dd.DD):
            return dd.add_float(acc, prod)
        # (k+1)-limb distillation: cheaper than lifting prod to a full
        # tier add
        return mp.from_limbs(mp.renorm_list(list(acc.limbs()) + [prod],
                                            k=k, sweeps=3))
    hi = prod.astype(limb_dtype)
    lo = (prod - hi.astype(prod.dtype)).astype(limb_dtype)
    if isinstance(acc, dd.DD):
        return dd.add(acc, dd.from_hi_lo(hi, lo))
    return mp.from_limbs(mp.renorm_list(list(acc.limbs()) + [hi, lo],
                                        k=k, sweeps=3))


def _slab_update(acc, a, b, *, beta, n_slices, slice_dtype, acc_dtype,
                 full):
    """One K-slab: extract slices, run the diagonal dots, fold into acc."""
    limb_dtype = a.limbs()[0].dtype
    sa = _extract_slices(a, beta, n_slices, axis=1)
    sb = _extract_slices(b, beta, n_slices, axis=0)
    narrow = jnp.dtype(slice_dtype) != jnp.dtype(limb_dtype)
    if narrow:
        # exact ladder normalization into the narrow dtype (shared with
        # core.ozaki._ozaki_impl; pair (s, t) then carries the residual
        # factor 2^(-(s+t)*beta), one rescale per diagonal)
        sa, sc_a = _normalize_slices(sa, beta, 1, slice_dtype)
        sb, sc_b = _normalize_slices(sb, beta, 0, slice_dtype)
    n_diag = (2 * n_slices - 1) if full else n_slices
    for d in range(n_diag):
        # the pair dots of diagonal d sum in acc_dtype (exact by the
        # slice_params headroom — every product sits on the diagonal's
        # common grid), then fold into the multi-limb VMEM accumulator once
        dsum = None
        for s, t in _diagonal_pairs(d, n_slices):
            p = jnp.dot(sa[s], sb[t],
                        preferred_element_type=jnp.dtype(acc_dtype))
            dsum = p if dsum is None else dsum + p
        if narrow:
            dsum = dsum.astype(limb_dtype) * \
                (sc_a * sc_b * (2.0 ** (-d * beta)))
        acc = _fold_diagonal(acc, dsum)
    return acc


def _ozgemm_kernel(*refs, nlimbs: int, beta: int, n_slices: int,
                   slice_dtype: str, acc_dtype: str, epilogue: str,
                   full: bool):
    # refs layout: nlimbs A + nlimbs B [+ nlimbs alpha (1,1)]
    #   [+ nlimbs beta (1,1) + nlimbs C] inputs, then nlimbs outputs, then
    #   nlimbs VMEM accumulator scratch
    a_refs = refs[:nlimbs]
    b_refs = refs[nlimbs:2 * nlimbs]
    pos = 2 * nlimbs
    alpha_refs = beta_refs = c_refs = ()
    if epilogue != "none":
        alpha_refs = refs[pos:pos + nlimbs]
        pos += nlimbs
    if epilogue == "full":
        beta_refs = refs[pos:pos + nlimbs]
        c_refs = refs[pos + nlimbs:pos + 2 * nlimbs]
        pos += 2 * nlimbs
    o_refs = refs[pos:pos + nlimbs]
    acc_refs = refs[pos + nlimbs:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        for r in acc_refs:
            r[...] = jnp.zeros_like(r)

    a = mp.from_limbs([r[...] for r in a_refs])  # (bm, bk)
    b = mp.from_limbs([r[...] for r in b_refs])  # (bk, bn)
    acc = _slab_update(
        mp.from_limbs([r[...] for r in acc_refs]), a, b,
        beta=beta, n_slices=n_slices,
        slice_dtype=slice_dtype, acc_dtype=acc_dtype, full=full)
    for r, v in zip(acc_refs, acc.limbs()):
        r[...] = v

    @pl.when(k == pl.num_programs(2) - 1)
    def _drain():
        res = mp.from_limbs([r[...] for r in acc_refs])
        if epilogue != "none":
            alpha = mp.from_limbs([r[...] for r in alpha_refs])  # (1, 1)
            res = mp.mul(mp.broadcast_to(alpha, res.shape), res)
        if epilogue == "full":
            beta_s = mp.from_limbs([r[...] for r in beta_refs])
            c = mp.from_limbs([r[...] for r in c_refs])
            bc = mp.mul(mp.broadcast_to(beta_s, c.shape), c)
            # BLAS: beta == 0 means C is NOT read — statically-zero betas
            # never reach the kernel (the engine drops C), but a beta that
            # is only zero at run time (traced epilogue operand) must not
            # leak NaN/Inf from C through 0 * C; the select discards the
            # poisoned product.  Same guard as engine._apply_epilogue.
            bc = mp.where(jnp.broadcast_to(mp.is_zero(beta_s), bc.shape),
                          mp.map_limbs(jnp.zeros_like, bc), bc)
            res = mp.add(res, bc)
        for o, v in zip(o_refs, res.limbs()):
            o[...] = v


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "beta", "n_slices",
                              "slice_dtype_name", "acc_dtype_name",
                              "epilogue", "full", "interpret")
)
def ozgemm_kernel_call(*operands, bm: int, bn: int, bk: int, beta: int,
                       n_slices: int, slice_dtype_name: str,
                       acc_dtype_name: str, epilogue: str = "none",
                       full: bool = False, interpret: bool = True):
    """Raw kernel invocation (block-multiple shapes only).

    ``operands``: nlimbs A limbs + nlimbs B limbs; with ``epilogue="alpha"``
    also nlimbs (1, 1) alpha limbs; with ``"full"`` additionally nlimbs
    (1, 1) beta limbs and nlimbs (m, n) C limbs.  Use the engine
    (``repro.gemm.execute`` with a ``backend="ozaki-pallas"`` plan) for the
    padded/public entry point.
    """
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; one of {EPILOGUES}")
    per_limb = {"none": 2, "alpha": 3, "full": 5}[epilogue]
    nlimbs, rem = divmod(len(operands), per_limb)
    assert rem == 0 and nlimbs in mp.PRECISIONS.values(), (
        len(operands), epilogue)
    a_limbs = operands[:nlimbs]
    m, k = a_limbs[0].shape
    k2, n = operands[nlimbs].shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n), (bm, bn, bk))
    dtype = a_limbs[0].dtype
    grid = (m // bm, n // bn, k // bk)

    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    in_specs = [a_spec] * nlimbs + [b_spec] * nlimbs
    if epilogue != "none":
        in_specs += [scalar_spec] * nlimbs
    if epilogue == "full":
        in_specs += [scalar_spec] * nlimbs + [c_spec] * nlimbs

    kern = functools.partial(
        _ozgemm_kernel, nlimbs=nlimbs, beta=beta, n_slices=n_slices,
        slice_dtype=slice_dtype_name, acc_dtype=acc_dtype_name,
        epilogue=epilogue, full=full)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[c_spec] * nlimbs,
        out_shape=[jax.ShapeDtypeStruct((m, n), dtype)] * nlimbs,
        scratch_shapes=[pltpu.VMEM((bm, bn), dtype)] * nlimbs,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
