"""Pallas systolic-tile kernel for quad-word (binary128+ class) GEMM.

The quad-limb sibling of ``kernels/ddgemm.py``: identical FPGA -> TPU
mapping (the (M/bm, N/bn) grid is the PE array, the sequential K grid
dimension is the systolic pulse, BlockSpec staging is the M_Tile buffer —
see DESIGN.md §2), but every operand/accumulator is **four** limb planes
instead of two, streamed through the same tile schedule.  This is the
runtime analogue of the parameterizable-precision FPGA designs (de Fine
Licht et al.): the architecture is fixed, the digit count is a knob.

The multiply-add inside a wave is the CAMPARY-style QD FMA from
``repro.core.qd``: exact partial-product decomposition + branch-free
renormalization sweeps, ~212 mantissa bits over f64 limbs.  Per-wave cost is
roughly an order of magnitude above the DD MAC, which is exactly the
precision/throughput trade the plan layer's ``precision`` axis exposes; the
autotune cache keys on limb count so QD tiles tune independently of DD's.

Validated in interpret mode against ``kernels/ref.qdgemm_ref`` by the
cross-backend conformance matrix (tests/test_conformance.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import qd

__all__ = ["qdgemm_kernel_call", "NLIMBS"]

NLIMBS = 4

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _qdgemm_kernel(*refs, bk: int):
    # refs: 4 A-limb refs, 4 B-limb refs, 4 out refs, 4 accumulator scratch
    a_refs, b_refs = refs[:NLIMBS], refs[NLIMBS:2 * NLIMBS]
    o_refs = refs[2 * NLIMBS:3 * NLIMBS]
    acc_refs = refs[3 * NLIMBS:]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        for r in acc_refs:
            r[...] = jnp.zeros_like(r)

    a = [r[...] for r in a_refs]  # (bm, bk) x 4 limbs
    b = [r[...] for r in b_refs]  # (bk, bn) x 4 limbs

    def wave(i, carry):
        # one systolic wave: acc += outer(a_col, b_row) in QD arithmetic;
        # (bm, 1) x (1, bn) broadcasts through the EFT chains to the tile
        a_col = qd.QD(*[
            jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1) for x in a])
        b_row = qd.QD(*[
            jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0) for x in b])
        out = qd.fma(qd.QD(*carry), a_col, b_row)
        return tuple(out.limbs())

    acc = jax.lax.fori_loop(0, bk, wave, tuple(r[...] for r in acc_refs))
    for r, v in zip(acc_refs, acc):
        r[...] = v

    @pl.when(k == pl.num_programs(2) - 1)
    def _store():
        for o, r in zip(o_refs, acc_refs):
            o[...] = r[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def qdgemm_kernel_call(*limbs, bm: int, bn: int, bk: int,
                       interpret: bool = True):
    """Raw kernel invocation on 4 A limbs + 4 B limbs (block multiples only).

    Use the engine (``repro.gemm.execute`` with a ``precision="qd"`` plan)
    for the padded/public entry point.
    """
    assert len(limbs) == 2 * NLIMBS, len(limbs)
    a_limbs, b_limbs = limbs[:NLIMBS], limbs[NLIMBS:]
    m, k = a_limbs[0].shape
    k2, n = b_limbs[0].shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n), (bm, bn, bk))
    dtype = a_limbs[0].dtype
    grid = (m // bm, n // bn, k // bk)
    out_shape = [jax.ShapeDtypeStruct((m, n), dtype)] * NLIMBS
    kern = functools.partial(_qdgemm_kernel, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=(
            [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))] * NLIMBS
            + [pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))] * NLIMBS
        ),
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))] * NLIMBS,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), dtype)] * NLIMBS,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*limbs)
