"""Pallas systolic-tile kernel for quad-word (binary128+ class) GEMM.

Thin 4-plane binding of the count-generic systolic kernel
(``kernels/mlgemm.py``): identical FPGA -> TPU mapping (the (M/bm, N/bn)
grid is the PE array, the sequential K grid dimension is the systolic
pulse, BlockSpec staging is the M_Tile buffer — see DESIGN.md §2), but
every operand/accumulator is **four** limb planes, streamed through the
same tile schedule.  This is the runtime analogue of the parameterizable-
precision FPGA designs (de Fine Licht et al.): the architecture is fixed,
the digit count is a knob.

The multiply-add inside a wave is the CAMPARY-style QD FMA from
``repro.core.qd``: exact partial-product decomposition + branch-free
renormalization sweeps, ~212 mantissa bits over f64 limbs.  Per-wave cost
is roughly an order of magnitude above the DD MAC, which is exactly the
precision/throughput trade the plan layer's ``precision`` axis exposes;
the autotune cache keys on limb count so QD tiles tune independently.

Validated in interpret mode against ``kernels/ref.qdgemm_ref`` by the
cross-backend conformance matrix (tests/test_conformance.py).
"""

from __future__ import annotations

from .mlgemm import mlgemm_kernel_call

__all__ = ["qdgemm_kernel_call", "NLIMBS"]

NLIMBS = 4


def qdgemm_kernel_call(*limbs, bm: int, bn: int, bk: int,
                       interpret: bool = True):
    """Raw kernel invocation on 4 A limbs + 4 B limbs (block multiples only).

    Use the engine (``repro.gemm.execute`` with a ``precision="qd"`` plan)
    for the padded/public entry point.
    """
    assert len(limbs) == 2 * NLIMBS, len(limbs)
    return mlgemm_kernel_call(*limbs, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
