"""Pure-jnp oracles for the extended-precision GEMM kernels.

These are the correctness references (the paper's CPU `Rgemm` analogue):
``mlgemm_ref`` is the count-generic exact-product + compensated-tree-
reduction matmul over ``core.mp``; ``ddgemm_ref``/``tdgemm_ref``/
``qdgemm_ref`` are its named tier bindings.  They favor clarity over speed
and are used by every kernel test as the allclose target.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dd, mp, qd, td

__all__ = ["mlgemm_ref", "ddgemm_ref", "tdgemm_ref", "qdgemm_ref",
           "gemm_f64_ref"]


def mlgemm_ref(a, b):
    """C = A @ B at the operands' tier: exact per-element products,
    compensated halving-tree accumulation over k.

    Shapes: a (m, k), b (k, n) -> (m, n).  Memory O(m*k*n) — test sizes only.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    abig = mp.map_limbs(lambda l: l[:, :, None], a)  # (m, k, 1)
    bbig = mp.map_limbs(lambda l: l[None, :, :], b)  # (1, k, n)
    prods = mp.mul(abig, bbig)  # (m, k, n) exact per-element tier products
    return mp.sum_(prods, axis=1)  # compensated halving-tree over k


def ddgemm_ref(a: dd.DD, b: dd.DD) -> dd.DD:
    """C = A @ B with DD inputs, exact products, DD tree accumulation."""
    return mlgemm_ref(a, b)


def tdgemm_ref(a: td.TD, b: td.TD) -> td.TD:
    """C = A @ B in triple-word arithmetic (small shapes only)."""
    return mlgemm_ref(a, b)


def qdgemm_ref(a: qd.QD, b: qd.QD) -> qd.QD:
    """C = A @ B in quad-word arithmetic (small shapes only)."""
    return mlgemm_ref(a, b)


def gemm_f64_ref(a, b):
    """Plain f64 matmul — the 'double' baseline the paper compares against."""
    return jnp.dot(jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64))
