"""Pure-jnp oracles for the extended-precision GEMM kernels.

These are the correctness references (the paper's CPU `Rgemm` analogue): a
vectorized exact-product + compensated-tree-reduction matmul in DD, and a
small-QD variant.  They favor clarity over speed and are used by every kernel
test as the allclose target.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dd, qd

__all__ = ["ddgemm_ref", "qdgemm_ref", "gemm_f64_ref"]


def ddgemm_ref(a: dd.DD, b: dd.DD) -> dd.DD:
    """C = A @ B with DD inputs, exact products, DD tree accumulation.

    Shapes: a (m, k), b (k, n) -> (m, n).  Memory O(m*k*n) — test sizes only.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    abig = dd.DD(a.hi[:, :, None], a.lo[:, :, None])  # (m, k, 1)
    bbig = dd.DD(b.hi[None, :, :], b.lo[None, :, :])  # (1, k, n)
    prods = dd.mul(abig, bbig)  # (m, k, n) exact per-element DD products
    return dd.sum_(prods, axis=1)  # compensated halving-tree reduction over k


def qdgemm_ref(a: qd.QD, b: qd.QD) -> qd.QD:
    """C = A @ B in quad-word arithmetic (small shapes only)."""
    m, k = a.shape
    _, n = b.shape
    al = [x[:, :, None] for x in a.limbs()]
    bl = [x[None, :, :] for x in b.limbs()]
    prods = qd.mul(qd.QD(*al), qd.QD(*bl))  # (m, k, n)
    cur = prods
    kk = k
    while kk > 1:
        half = kk // 2
        left = qd.QD(*[l[:, :half, :] for l in cur.limbs()])
        right = qd.QD(*[l[:, half : 2 * half, :] for l in cur.limbs()])
        red = qd.add(left, right)
        if kk % 2:
            tail = qd.QD(*[l[:, -1:, :] for l in cur.limbs()])
            red = qd.add(
                red,
                qd.QD(
                    *[
                        jnp.concatenate([t, jnp.zeros_like(r[:, 1:, :])], axis=1)
                        for t, r in zip(tail.limbs(), red.limbs())
                    ]
                ),
            )
        cur = red
        kk = half
    return qd.QD(*[l[:, 0, :] for l in cur.limbs()])


def gemm_f64_ref(a, b):
    """Plain f64 matmul — the 'double' baseline the paper compares against."""
    return jnp.dot(jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64))
