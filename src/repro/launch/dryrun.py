import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, record memory/cost analysis + roofline terms.

The two lines above MUST precede any jax-importing import: jax locks the
device count at first backend init, and only this entry point is allowed to
force the 512-device host emulation (tests and benches see 1 device).

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, skip_reason  # noqa: E402
from repro.configs.registry import ALL_ARCHS  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.hlo_cost import analyze_hlo  # noqa: E402
from repro.launch.roofline import roofline_report  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.runtime.sharding import ShardingRules, activate  # noqa: E402


def _dp_axes(rules):
    return tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)


def _divisible_axis_spec(rules, shape, prefer_dims, mesh_axis="model"):
    """First dim in prefer_dims divisible by the mesh axis gets sharded."""
    size = rules.mesh.shape[mesh_axis]
    for d in prefer_dims:
        if shape[d] % size == 0 and shape[d] >= size:
            spec = [None] * len(shape)
            spec[d] = mesh_axis
            return spec
    return [None] * len(shape)


def decode_state_shardings(cfg, state_like, rules: ShardingRules, batch: int):
    """Shard decode caches: kv-heads (or seq) over model, batch over data."""
    dp = _dp_axes(rules)
    dp_total = 1
    for a in dp:
        dp_total *= rules.mesh.shape[a]

    def leaf_spec(leaf):
        shape = leaf.shape
        spec = _divisible_axis_spec(rules, shape, _model_dims(shape))
        # batch dim: the dim equal to `batch` (first occurrence), only if
        # divisible by the dp extent
        if batch > 1 and batch % dp_total == 0:
            for i, s in enumerate(shape):
                if s == batch and spec[i] is None:
                    spec[i] = dp
                    break
        return NamedSharding(rules.mesh,
                             S.validate_spec(rules.mesh, P(*spec), shape))

    def _model_dims(shape):
        # prefer head-like dims (== n_kv_heads / n_heads), then large dims
        cands = [i for i, s in enumerate(shape)
                 if s in (cfg.n_kv_heads, cfg.n_heads)]
        cands += [i for i, s in enumerate(shape)
                  if s >= 256 and i not in cands]
        return cands

    return jax.tree.map(leaf_spec, state_like)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               run_overrides: dict | None = None, rules_overrides=None):
    """Lower + compile one cell. Returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh=mesh)
    if rules_overrides:
        rules = rules_overrides(rules)
    n_dev = mesh.size
    t0 = time.time()

    with activate(rules):
        if shape.kind == "train":
            run_cfg = S.default_run_config(arch, **(run_overrides or {}))
            step_fn = S.build_train_step(cfg, run_cfg)
            state_sds = S.state_specs(cfg, run_cfg)
            state_sh = S.state_shardings(cfg, run_cfg, rules)
            specs = M.input_specs(cfg, shape)
            batch_sh = S.batch_shardings(cfg, shape.kind, rules, specs)
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, specs)
        elif shape.kind == "prefill":
            run_cfg = S.default_run_config(arch)
            step_fn = S.build_encode_step(cfg)
            params_sds = S.state_specs(cfg, run_cfg).params
            params_sh = S.state_shardings(cfg, run_cfg, rules).params
            specs = M.input_specs(cfg, shape)
            batch_sh = S.batch_shardings(cfg, shape.kind, rules, specs)
            out_shape = jax.eval_shape(step_fn, params_sds, specs)
            logits_sh = NamedSharding(
                rules.mesh,
                S.validate_spec(rules.mesh,
                                P(_dp_axes(rules), None, "model"),
                                out_shape.shape))
            lowered = jax.jit(
                step_fn,
                in_shardings=(params_sh, batch_sh),
                out_shardings=logits_sh,
            ).lower(params_sds, specs)
        else:  # decode
            run_cfg = S.default_run_config(arch, param_dtype="bfloat16",
                                           optimizer="adamw")
            serve = S.build_serve_step(cfg)
            params_sds = S.state_specs(cfg, run_cfg).params
            params_sh = S.state_shardings(cfg, run_cfg, rules).params
            cache_sds = jax.eval_shape(
                lambda: M.init_decode_state(cfg, shape.global_batch,
                                            shape.seq_len))
            cache_sh = decode_state_shardings(cfg, cache_sds, rules,
                                              shape.global_batch)
            specs = M.input_specs(cfg, shape)
            tok_sh = S.batch_shardings(cfg, shape.kind, rules,
                                       {"tokens": specs["tokens"]})["tokens"]
            lspec = P(None, "model") if shape.global_batch == 1 \
                else P(_dp_axes(rules), "model")
            logits_sh = NamedSharding(
                rules.mesh,
                S.validate_spec(rules.mesh, lspec,
                                (shape.global_batch, cfg.vocab_size)))
            lowered = jax.jit(
                serve,
                in_shardings=(params_sh, cache_sh, tok_sh, None),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, specs["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    # NOTE: compiled.cost_analysis() counts while-loop bodies ONCE (verified
    # empirically) — useless for scanned models.  analyze_hlo re-derives
    # per-device costs with loop trip counts (launch/hlo_cost.py).
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    flops = cost.flops
    bytes_acc = cost.dot_bytes
    coll = {"total": cost.collective_wire_bytes,
            **cost.collective_by_kind, "counts": cost.collective_counts}
    roof = roofline_report(cfg, shape, flops_per_dev=flops,
                           bytes_per_dev=bytes_acc, coll=coll,
                           n_devices=n_dev)
    per_dev_bytes = {
        "argument_size": mem.argument_size_in_bytes,
        "output_size": mem.output_size_in_bytes,
        "temp_size": mem.temp_size_in_bytes,
        "alias_size": mem.alias_size_in_bytes,
        "peak_estimate": (mem.argument_size_in_bytes
                          + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes
                          - mem.alias_size_in_bytes),
    }
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_per_device": per_dev_bytes,
        "fits_16gb": per_dev_bytes["peak_estimate"] < 16e9,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "roofline": roof,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} [{'2x16x16' if mp else '16x16'}]"
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                if rec.get("skipped"):
                    print(f"SKIP  {label}: {rec['skipped']}", flush=True)
                elif rec.get("error"):
                    print(f"FAIL  {label}: {rec['error']}", flush=True)
                else:
                    r = rec["roofline"]
                    print(
                        f"OK    {label}: mem/dev "
                        f"{rec['memory_per_device']['peak_estimate']/1e9:.2f}GB "
                        f"compute {r['compute_s']*1e3:.2f}ms "
                        f"memory {r['memory_s']*1e3:.2f}ms "
                        f"coll {r['collective_s']*1e3:.2f}ms "
                        f"-> {r['bottleneck']} "
                        f"frac {r['roofline_fraction']:.3f}",
                        flush=True)
                results.append(rec)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    ok = sum(1 for r in results if "roofline" in r)
    fail = sum(1 for r in results if "error" in r)
    skip = sum(1 for r in results if "skipped" in r)
    print(f"\n{ok} compiled, {skip} skipped (by design), {fail} failed")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
