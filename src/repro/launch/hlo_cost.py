"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
scanned 126-layer model with gradient-accumulation scans the reported flops
are off by orders of magnitude (verified empirically; see EXPERIMENTS.md
§Roofline-methodology).  This module re-derives costs structurally:

  1. split the HLO module into computations,
  2. recover each while loop's trip count from the constant in its condition
     computation (scan lowers to `count < K` comparisons),
  3. propagate execution multipliers through the call graph
     (while bodies x trip count; fusions/calls x 1),
  4. count per-op costs: dot flops (2 * prod(result) * prod(contracted)),
     dot/parameter memory traffic, and collective wire bytes (ring model).

Elementwise flops are ignored (dominated by dots at these shapes); memory
traffic is the fusion-agnostic sum of dot operand/result bytes plus
collective payloads, a deliberate upper-ish bound documented with the
roofline results.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
# type is either a tuple "(...)" (may contain /*index=N*/ comments, hence
# no '=' exclusion — tuples never nest parens) or a scalar/array type
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\/ ]+?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_CMP = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^)]*\}|\[[\d,]+\]<=\[\d+\])")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dtype]
    return elems_total, bytes_total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    line: str


def _split_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                if line.strip().startswith("ENTRY"):
                    entry = cur
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), line))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond_ops: List[_Op]) -> int:
    """Loop bound from the condition computation's comparison constant.

    scan lowers to `induction < K`; with several constants present take the
    max positive one (the bound dominates counters/offsets).
    """
    best = 1
    for op in cond_ops:
        if op.kind == "constant":
            m = _CONST_CMP.search(op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    spec = m.group(1)
    if spec.startswith("{{"):
        first = spec[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    dims = spec[1:spec.index("]")].split(",")
    return int(dims[-1]) if dims else 2


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    while_trip_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCost", mult: float):
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult


def _operand_names(line: str, kind: str) -> List[str]:
    """Names inside the op's operand parens.

    Handles both HLO text styles — bare names ``dot(%a, %b)`` and typed
    operands ``dot(f32[8,8]{1,0} %a, ...)`` (newer XLA dumps) — by scanning
    to the matching close paren (tuple-typed operands nest) and pulling the
    ``%name`` tokens."""
    start = line.index(kind + "(") + len(kind)
    depth = 0
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return _NAME_RE.findall(line[start + 1:i])
    return []


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> Tuple[float, float]:
    """(flops, bytes) for a dot given the symbol shape table."""
    res_elems, res_bytes = _shape_elems_bytes(op.type_str)
    names = _operand_names(op.line, op.kind)
    operand_bytes = 0
    lhs_name = names[0] if names else None
    for n in names:
        if n in shapes:
            operand_bytes += _shape_elems_bytes(shapes[n])[1]
    # contracted extent from the lhs shape + contracting dims
    contracted = 1
    mdims = _DOT_DIMS.search(op.line)
    if mdims and lhs_name and lhs_name in shapes:
        dims_str = _SHAPE_RE.search(shapes[lhs_name])
        if dims_str:
            dims = [int(d) for d in dims_str.group(2).split(",") if d]
            for idx in mdims.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contracted *= dims[int(idx)]
    flops = 2.0 * res_elems * contracted
    return flops, float(operand_bytes + res_bytes)


def _local_cost(ops: List[_Op], shapes: Dict[str, str]) -> HloCost:
    c = HloCost()
    for op in ops:
        if op.kind == "dot":
            f, b = _dot_flops(op, shapes)
            c.flops += f
            c.dot_bytes += b
        else:
            kind = op.kind.replace("-start", "")
            if kind in _COLLECTIVES:
                _, size = _shape_elems_bytes(op.type_str)
                g = _group_size(op.line)
                if kind == "all-gather":
                    wire = size * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = size * (g - 1)
                elif kind == "all-reduce":
                    wire = 2 * size * (g - 1) / g
                elif kind == "all-to-all":
                    wire = size * (g - 1) / g
                else:
                    wire = size
                c.collective_wire_bytes += wire
                c.collective_by_kind[kind] = c.collective_by_kind.get(kind, 0.0) + wire
                c.collective_counts[kind] = c.collective_counts.get(kind, 0) + 1
    return c


def analyze_hlo(hlo: str) -> HloCost:
    comps = _split_computations(hlo)
    entry_ops = comps.get("__entry__")
    if entry_ops is None:
        return HloCost()
    shape_tables = {
        name: {op.name: op.type_str for op in ops}
        for name, ops in comps.items()
    }
    local = {name: _local_cost(ops, shape_tables[name])
             for name, ops in comps.items()}
    total = HloCost()
    # iterative DFS from entry with multipliers
    stack: List[Tuple[str, float]] = [("__entry__", 1.0)]
    seen_guard = 0
    while stack:
        seen_guard += 1
        if seen_guard > 100000:
            break
        name, mult = stack.pop()
        ops = comps.get(name)
        if ops is None:
            continue
        total.add(local[name], mult)
        for op in ops:
            if op.kind == "while":
                m = _WHILE_RE.search(op.line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                total.while_trip_counts[body] = trips
                stack.append((body, mult * trips))
            elif op.kind in ("fusion", "call", "custom-call", "conditional",
                             "map", "reduce", "reduce-window", "scatter",
                             "sort", "select-and-scatter", "all-reduce",
                             "reduce-scatter"):
                for sub in _CALLS_RE.findall(op.line):
                    if sub in comps and sub != name:
                        stack.append((sub, mult))
    return total
