"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device counts are locked at first backend init,
and only launch/dryrun.py is allowed to force the 512-device emulation.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "compat_make_mesh"]


def compat_make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions.

    axis_types/AxisType only landed after 0.4.x, and explicit Auto axes
    keep newer versions from warning."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, devices=devices, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under launch/dryrun.py (it forces host-device emulation) or on "
            "real hardware")
    return compat_make_mesh(shape, axes, devices=devices)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (tests / single-host training)."""
    n = len(jax.devices())
    data = data if data is not None else max(1, n // model)
    devices = jax.devices()[: data * model]
    return compat_make_mesh((data, model), ("data", "model"), devices=devices)
