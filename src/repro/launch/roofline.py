"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds-per-step, derived from
the per-device compiled program:

  compute    = HLO_flops_per_device / peak_flops          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_device / hbm_bw              (819 GB/s)
  collective = wire_bytes_per_device / link_bw            (~50 GB/s/link)

cost_analysis() provides flops and bytes; collective bytes are parsed from
the optimized HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's operand/result sizes, weighted by the
standard ring-algorithm wire factors:

  all-gather      out_bytes * (g-1)/g
  reduce-scatter  out_bytes * (g-1)          (input is g x output)
  all-reduce      2 * bytes * (g-1)/g
  all-to-all      bytes * (g-1)/g
  collective-permute  bytes

MODEL_FLOPS (6*N*D for training, 2*N_active*D for inference forward) gives
the useful-compute ratio — remat recompute and padding waste show up as
HLO_flops >> MODEL_FLOPS.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

__all__ = ["HW", "parse_collective_bytes", "roofline_report", "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 per chip, TPU v5e
    "hbm_bw": 819e9,        # bytes/s
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^)]*\}|\[[\d,]+\]<=\[\d+\])")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    spec = m.group(1)
    if spec.startswith("{{"):
        first = spec[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    dims = spec[1:spec.index("]")].split(",")
    return int(dims[-1]) if dims else 2


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (ring-algorithm model)."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        g = _group_size(line)
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)
        elif kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:
            wire = size
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def model_flops(cfg, shape) -> float:
    """Analytic useful flops per step for the whole cell (all chips)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.seq_len, shape.global_batch) * 3
    elif shape.kind == "prefill":
        base = 2.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.seq_len, shape.global_batch)
    else:  # decode: one token against a seq_len cache
        base = 2.0 * n_active * shape.global_batch
        attn = _decode_attn_flops(cfg, shape.seq_len, shape.global_batch)
    return base + attn


def _attn_flops(cfg, s, b) -> float:
    if cfg.family in ("ssm",):
        return 0.0
    n_attn = cfg.n_layers if cfg.family != "hybrid" \
        else cfg.n_layers // max(cfg.attn_every, 1)
    return 4.0 * b * n_attn * cfg.n_heads * cfg.head_dim * s * s / 2


def _decode_attn_flops(cfg, s, b) -> float:
    if cfg.family == "ssm":
        return 0.0
    n_attn = cfg.n_layers if cfg.family != "hybrid" \
        else cfg.n_layers // max(cfg.attn_every, 1)
    return 4.0 * b * n_attn * cfg.n_heads * cfg.head_dim * s


def roofline_report(cfg, shape, *, flops_per_dev: float, bytes_per_dev: float,
                    coll: dict, n_devices: int, hw: Optional[dict] = None) -> dict:
    hw = hw or HW
    t_comp = flops_per_dev / hw["peak_flops"]
    t_mem = bytes_per_dev / hw["hbm_bw"]
    t_coll = coll["total"] / hw["link_bw"]
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_per_dev * n_devices
    step_s = max(t_comp, t_mem, t_coll)
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
        "roofline_step_s": step_s,
        # fraction of the chips' peak the USEFUL flops achieve at the
        # roofline-implied step time — the headline perf score
        "roofline_fraction": (mf / (n_devices * hw["peak_flops"])) / step_s
        if step_s else float("nan"),
    }
