"""Batched serving driver: continuous-batching decode loop.

A minimal production-shaped server: a request queue, a fixed decode batch
with slot management (finished sequences are replaced by queued prefills),
greedy sampling, and per-slot state carried in the shared KV/SSM cache.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --requests 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as S
from repro.launch.train import reduce_cfg
from repro.models import model as M

__all__ = ["BatchedServer", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-batch continuous server over decode_step."""

    def __init__(self, cfg, params, batch_slots: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.max_len = max_len
        self.state = M.init_decode_state(cfg, batch_slots, max_len,
                                         dtype=jnp.float32)
        self.step_fn = jax.jit(S.build_serve_step(cfg))
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.decode_steps = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # prefill by stepping the prompt through decode slots
                # (single-token prefill keeps one compiled program; a batched
                # prefill path is the documented optimization)
                self.pos[i] = 0
                req._cursor = 0  # type: ignore[attr-defined]

    def step(self):
        """One decode step for the whole batch."""
        self._admit()
        toks = np.zeros((len(self.slots), 1), np.int32)
        active = np.zeros(len(self.slots), bool)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = req._cursor  # type: ignore[attr-defined]
            if cur < len(req.prompt):
                toks[i, 0] = req.prompt[cur]
            elif req.generated:
                toks[i, 0] = req.generated[-1]
            active[i] = True
        if not active.any():
            return False
        # batch is positionally aligned: step at max position, slots that
        # lag simply re-attend (greedy demo server)
        pos = int(self.pos[active].max())
        logits, self.state = self.step_fn(
            self.params, self.state, jnp.asarray(toks), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.decode_steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req._cursor += 1  # type: ignore[attr-defined]
            self.pos[i] += 1
            if req._cursor > len(req.prompt):  # type: ignore[attr-defined]
                req.generated.append(int(nxt[i]))
            elif req._cursor == len(req.prompt):  # type: ignore[attr-defined]
                req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new or self.pos[i] >= self.max_len - 1:
                req.done = True
                self.completed.append(req)
                self.slots[i] = None
                self.pos[i] = 0
        return True

    def run(self):
        while self.queue or any(s is not None for s in self.slots):
            if not self.step():
                break
        return self.completed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduce_cfg(get_config(args.arch))
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no serving path")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, batch_slots=args.slots)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(3, 10)).tolist()
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = server.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, "
          f"{server.decode_steps} decode steps in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt {r.prompt[:5]}... -> {r.generated}")


if __name__ == "__main__":
    main()
