"""Step builders shared by the dry-run, the trainer, and the server.

Everything here is mesh-agnostic: functions close over (cfg, run_cfg) and
get distribution purely from in/out shardings + the logical-axis constraint
context (runtime/sharding.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, RunConfig
from repro.models import model as M
from repro.optim import make_optimizer
from repro.runtime.sharding import ShardingRules

__all__ = [
    "TrainState",
    "build_train_step",
    "build_serve_step",
    "build_encode_step",
    "state_specs",
    "state_shardings",
    "batch_shardings",
    "default_run_config",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray


# per-arch run-config overrides that make the big cells fit 256 v5e chips:
# remat=full + gradient-accumulation microbatching bound the activation
# footprint; int8 optimizer states + bf16 params bound the state footprint
_RUN_OVERRIDES = {
    "llama3-405b": dict(param_dtype="bfloat16", optimizer="adamw_int8",
                        microbatches=16, remat="full"),
    "qwen3-moe-235b-a22b": dict(optimizer="adamw_int8", microbatches=16,
                                remat="full"),
    "mistral-nemo-12b": dict(microbatches=8, remat="full"),
    "llama-3.2-vision-11b": dict(microbatches=8, remat="full"),
    "moonshot-v1-16b-a3b": dict(microbatches=8, remat="full"),
    "qwen3-4b": dict(microbatches=4, remat="full"),
    "qwen3-0.6b": dict(microbatches=4, remat="full"),
    "zamba2-2.7b": dict(microbatches=4, remat="full"),
    "xlstm-350m": dict(microbatches=2, remat="full"),
    "hubert-xlarge": dict(microbatches=4, remat="full"),
}


def default_run_config(arch: str, **extra) -> RunConfig:
    kw = dict(_RUN_OVERRIDES.get(arch, {}))
    kw.update(extra)
    return RunConfig(**kw)


def init_state(cfg: ArchConfig, run_cfg: RunConfig, key):
    dtype = jnp.dtype(run_cfg.param_dtype)
    params = M.init_params(cfg, key, dtype)
    opt_init, _ = make_optimizer(run_cfg)
    return TrainState(params=params, opt=opt_init(params),
                      step=jnp.zeros((), jnp.int32))


def state_specs(cfg: ArchConfig, run_cfg: RunConfig):
    """Abstract state (ShapeDtypeStructs) without allocating anything."""
    return jax.eval_shape(
        lambda: init_state(cfg, run_cfg, jax.random.PRNGKey(0)))


def validate_spec(mesh, spec: P, shape) -> P:
    """Drop mesh axes whose extent does not divide the dimension (jit

    in_shardings require exact divisibility, unlike constraints)."""
    out = []
    for dim, val in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if val is None:
            out.append(None)
            continue
        axes = val if isinstance(val, tuple) else (val,)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        out.append(val if dim % extent == 0 and dim >= extent else None)
    return P(*out)


def _param_spec_tree(cfg, params_like, rules: ShardingRules):
    axes = M.param_logical_axes(cfg, params_like)
    return jax.tree.map(
        lambda leaf, names: NamedSharding(
            rules.mesh,
            validate_spec(rules.mesh, rules.param_spec(*names), leaf.shape)),
        params_like, axes)


def constrain_like_params(cfg, tree, params_like=None):
    """Sharding-constrain a param-shaped tree (e.g. grad accumulators) to

    the parameter sharding rules.  No-op outside an active rules context.
    The gradient-accumulation buffer MUST be constrained: unconstrained
    zeros in the scan carry replicate, which for llama3-405b is a 1.6 TB
    per-device buffer (observed before this fix)."""
    from repro.runtime.sharding import current

    rules = current()
    if rules is None:
        return tree
    shardings = _param_spec_tree(cfg, params_like or tree, rules)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings)


def _dp_spec(rules: ShardingRules, leaf):
    """ZeRO sharding for non-param-shaped optimizer leaves (int8 blocks)."""
    dp = tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)
    if leaf.ndim >= 1 and leaf.shape[0] >= 2:
        return NamedSharding(rules.mesh, P(dp, *([None] * (leaf.ndim - 1))))
    return NamedSharding(rules.mesh, P())


def state_shardings(cfg: ArchConfig, run_cfg: RunConfig, rules: ShardingRules):
    st = state_specs(cfg, run_cfg)
    p_sh = _param_spec_tree(cfg, st.params, rules)

    # int8 states quantize per-row, so (q, scale) leaves keep the param's
    # shape (scale has a size-1/2 trailing dim that validate_spec strips):
    # every optimizer leaf shares the param logical axes.
    if run_cfg.optimizer == "adamw_int8":
        axes = M.param_logical_axes(cfg, st.params)

        def qspec(names, leaf):
            return NamedSharding(
                rules.mesh,
                validate_spec(rules.mesh, rules.param_spec(*names), leaf.shape))

        m_sh = jax.tree.map(
            lambda pax, mq: tuple(qspec(pax, leaf) for leaf in mq),
            axes, st.opt.m, is_leaf=lambda x: isinstance(x, tuple) and not hasattr(x, "shape"))
        v_sh = jax.tree.map(
            lambda pax, vq: tuple(qspec(pax, leaf) for leaf in vq),
            axes, st.opt.v, is_leaf=lambda x: isinstance(x, tuple) and not hasattr(x, "shape"))
    else:
        m_sh = _param_spec_tree(cfg, st.opt.m, rules)
        v_sh = _param_spec_tree(cfg, st.opt.v, rules)
    master_sh = None
    if st.opt.master_lo is not None:
        master_sh = _param_spec_tree(cfg, st.opt.master_lo, rules)
    from repro.optim.adamw import OptState

    return TrainState(
        params=p_sh,
        opt=OptState(NamedSharding(rules.mesh, P()), m_sh, v_sh, master_sh),
        step=NamedSharding(rules.mesh, P()),
    )


def batch_shardings(cfg: ArchConfig, shape_kind: str, rules: ShardingRules,
                    specs: dict):
    """Input shardings: batch over DP axes; long-context batch=1 shards seq;
    anything non-divisible falls back to replication (validate_spec)."""
    dp = tuple(a for a in ("pod", "data") if a in rules.mesh.axis_names)
    out = {}
    for name, spec in specs.items():
        if name == "pos":
            out[name] = NamedSharding(rules.mesh, P())
            continue
        ndim = len(spec.shape)
        if spec.shape[0] == 1 and ndim >= 2 and spec.shape[1] > 1:
            # batch=1 long-context: sequence parallelism over data axis
            p = P(None, dp, *([None] * (ndim - 2)))
        else:
            p = P(dp, *([None] * (ndim - 1)))
        out[name] = NamedSharding(rules.mesh,
                                  validate_spec(rules.mesh, p, spec.shape))
    return out


def build_train_step(cfg: ArchConfig, run_cfg: RunConfig):
    _, opt_update = make_optimizer(
        run_cfg, constrain=lambda tree: constrain_like_params(cfg, tree))
    mb = run_cfg.microbatches
    policy = run_cfg.policy or None

    def loss_fn(params, batch):
        loss, parts = M.train_loss(params, cfg, batch, policy=policy,
                                   remat=run_cfg.remat)
        return loss, parts

    grad_dtype = jnp.dtype(run_cfg.param_dtype) \
        if run_cfg.param_dtype == "bfloat16" else jnp.float32

    def train_step(state: TrainState, batch: dict):
        if mb <= 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            mbatch = {k: split(v) for k, v in batch.items()}
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_dtype), state.params)
            zero = constrain_like_params(cfg, zero, state.params)

            def body(carry, mb_batch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb_batch)
                # constrain the RAW microbatch grad too: otherwise GSPMD
                # all-reduces each microbatch's full gradient before the
                # (sharded) accumulation — 8.5 TB/step of avoidable wire at
                # 405B scale (§Perf iteration B2)
                g = constrain_like_params(cfg, g, state.params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(grad_dtype), g_acc, g)
                g_acc = constrain_like_params(cfg, g_acc, state.params)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(
                body, (zero, jnp.float32(0.0)), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
        new_params, new_opt, info = opt_update(grads, state.opt, state.params)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        metrics = {"loss": loss.astype(jnp.float32), **info}
        return new_state, metrics

    return train_step


def build_serve_step(cfg: ArchConfig, policy=None):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(params, cfg, cache, tokens, pos, policy=policy)

    return serve_step


def build_encode_step(cfg: ArchConfig, policy=None):
    """Encoder-only / prefill forward (no loss)."""

    def encode_step(params, batch):
        logits, _ = M.forward_logits(params, cfg, batch, policy=policy)
        return logits

    return encode_step
