"""End-to-end training driver (runs for real on whatever devices exist).

Wires together: arch config -> model -> optimizer -> sharded train step ->
deterministic data pipeline -> checkpoint manager -> failover loop.
On CPU this trains the reduced/example configs; on a TPU fleet the same
driver runs the production mesh (mesh construction is the only difference).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \\
      --reduce --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import DataConfig, TokenStream
from repro.launch import steps as S
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.runtime.failover import StepWatchdog, run_with_restarts
from repro.runtime.sharding import ShardingRules, activate

__all__ = ["train", "reduce_cfg"]


def reduce_cfg(cfg, d_model=256, n_layers=None, vocab=2048):
    """~100M-class reduced config of the same family (for CPU examples)."""
    per = (cfg.attn_every or cfg.slstm_every or cfg.cross_attn_every or 0)
    layers = n_layers or (2 * per if per else 4)
    return dataclasses.replace(
        cfg,
        n_layers=layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=vocab,
        head_dim=64,
        n_experts=min(cfg.n_experts, 8) or 0,
        experts_per_token=min(cfg.experts_per_token, 2) or 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) or 0,
        n_modality_tokens=min(cfg.n_modality_tokens, 16) or 0,
    )


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 256,
          reduce: bool = True, ckpt_dir: str | None = None,
          run_cfg: RunConfig | None = None, log_every: int = 10,
          inject_failure_at: int | None = None, verbose: bool = True):
    cfg = get_config(arch)
    if reduce:
        cfg = reduce_cfg(cfg)
    run_cfg = run_cfg or RunConfig(
        learning_rate=1e-3, warmup_steps=max(10, steps // 20),
        total_steps=steps, param_dtype="float32", microbatches=1)
    mesh = make_local_mesh()
    rules = ShardingRules(mesh=mesh)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=run_cfg.seed)
    stream = TokenStream(data_cfg)
    mgr = CheckpointManager(ckpt_dir, async_save=True) if ckpt_dir else None
    losses: list = []
    injected = {"done": False}  # one-shot failure injection

    with activate(rules):
        train_step = jax.jit(S.build_train_step(cfg, run_cfg), donate_argnums=(0,))

        def make_state(restore_step):
            if restore_step is None or mgr is None:
                state = S.init_state(cfg, run_cfg, jax.random.PRNGKey(run_cfg.seed))
                return state, 0
            template = jax.eval_shape(
                lambda: S.init_state(cfg, run_cfg, jax.random.PRNGKey(0)))
            host, meta = mgr.restore(template)
            state = jax.tree.map(jnp.asarray, host)
            return state, meta["step"]

        def step_fn(state, step):
            if (inject_failure_at is not None and step == inject_failure_at
                    and not injected["done"]):
                from repro.runtime.failover import SimulatedFailure

                injected["done"] = True
                raise SimulatedFailure(f"injected at {step}")
            raw = stream.batch_at(step)
            batch_dev = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.family == "vlm":
                rng = np.random.default_rng(step)
                batch_dev["image_embeds"] = jnp.asarray(
                    rng.standard_normal(
                        (batch, cfg.n_modality_tokens, cfg.d_model)),
                    jnp.float32)
            if cfg.family == "audio":
                emb = np.asarray(state.params["embed"])
                feats = emb[np.asarray(raw["tokens"])]
                batch_dev = {"features": jnp.asarray(feats),
                             "labels": batch_dev["labels"]}
            state, metrics = train_step(state, batch_dev)
            loss = float(metrics["loss"])
            losses.append(loss)
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            return state

        watchdog = StepWatchdog(threshold=5.0)
        if mgr is not None:
            state, step, failures = run_with_restarts(
                make_state, step_fn, mgr, total_steps=steps,
                checkpoint_every=max(steps // 5, 10), watchdog=watchdog)
        else:
            state, _ = make_state(None)
            for i in range(steps):
                t0 = time.monotonic()
                state = step_fn(state, i)
                watchdog.observe(i, time.monotonic() - t0)
            failures = 0
    return {"losses": losses, "state": state, "failures": failures,
            "stragglers": watchdog.stragglers}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true", help="no config reduction")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                reduce=not args.full, ckpt_dir=args.ckpt_dir)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")


if __name__ == "__main__":
    main()
