"""Shared transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-JAX modules: parameters are nested dicts of arrays, apply functions are
plain functions.  Every tensor-parallel-relevant intermediate is annotated
with logical axis names via runtime.sharding.constrain (no-op off-mesh).

Precision policy hooks: dense projections route through policy.pmatmul so
any site can be switched to the extended-precision GEMM engine (DESIGN.md
§3) — the paper's technique as a first-class feature.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from .policy import pmatmul

__all__ = [
    "rmsnorm",
    "rope",
    "init_dense",
    "init_norm",
    "init_attention",
    "init_mlp",
    "attention",
    "cross_attention",
    "mlp",
    "KVCache",
]


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = d_in ** -0.5
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32).astype(dtype) * scale


def init_norm(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x, w, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * w


def rope(x, positions, theta: float = 1e6):
    """Rotary embedding. x: (..., seq, heads, head_dim), positions (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray  # (batch, max_len, kv_heads, head_dim)
    v: jnp.ndarray


def init_attention(key, cfg, d_model: int | None = None, dtype=jnp.float32,
                   n_heads: int | None = None, n_kv: int | None = None):
    d_model = d_model or cfg.d_model
    n_heads = n_heads or cfg.n_heads
    n_kv = n_kv or cfg.n_kv_heads
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d_model, n_heads * hd, dtype),
        "wk": init_dense(ks[1], d_model, n_kv * hd, dtype),
        "wv": init_dense(ks[2], d_model, n_kv * hd, dtype),
        "wo": init_dense(ks[3], n_heads * hd, d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, dtype)
        p["k_norm"] = init_norm(hd, dtype)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def attention(p, x, cfg, *, positions, mask=None, cache: Optional[KVCache] = None,
              cache_pos=None, causal: bool = True, policy=None):
    """GQA attention with optional qk_norm, RoPE, KV cache (decode).

    x: (batch, seq, d_model).  With cache: seq == 1 decode step writing at
    cache_pos, attending to cache[: cache_pos + 1].
    """
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(pmatmul(x, p["wq"], "attn_qkv", policy), nh, hd)
    k = _split_heads(pmatmul(x, p["wk"], "attn_qkv", policy), nkv, hd)
    v = _split_heads(pmatmul(x, p["wv"], "attn_qkv", policy), nkv, hd)
    # constrain q only: kv_heads is often smaller than the model axis
    # (GQA kv=8 on a 16-way axis) and forcing it causes involuntary
    # reshard/remat copies; GSPMD propagates k/v sharding from q
    q = constrain(q, "batch", "seq", "heads", None)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        # decode: write this step's k/v at cache_pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache_pos, axis=1)
        k_all, v_all = k_cache, v_cache
        new_cache = KVCache(k_cache, v_cache)
        kv_len = cache.k.shape[1]
        kv_pos = jnp.arange(kv_len)
        valid = kv_pos[None, :] <= (cache_pos + jnp.zeros((b, 1), jnp.int32))
    else:
        k_all, v_all = k, v
        new_cache = None
        kv_len = s
        valid = None

    # grouped heads: (b, s, nh, hd) x (b, t, nkv, hd); group q heads per kv
    group = nh // nkv
    q = q.reshape(b, s, nkv, group, hd)
    logits = jnp.einsum("bsngh,btnh->bnsgt", q.astype(jnp.float32) if False else q,
                        k_all, preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    if causal and cache is None:
        qpos = positions[..., :, None]           # (b, s, 1)
        kpos = jnp.arange(kv_len)[None, None, :]  # (1, 1, t)
        cmask = kpos <= qpos                     # (b, s, t)
        logits = jnp.where(cmask[:, None, :, None, :], logits, -1e30)
    if valid is not None:
        logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    if mask is not None:
        logits = jnp.where(mask[:, None, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_all.dtype)
    out = jnp.einsum("bnsgt,btnh->bsngh", probs, v_all,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, s, nh * hd)
    out = pmatmul(out, p["wo"], "attn_out", policy)
    return constrain(out, "batch", "seq", None), new_cache


def init_cross_attention(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    hd = cfg.head_dim
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
        "gate": jnp.zeros((1,), dtype),  # zero-init tanh gate (llama-3.2 style)
    }


def cross_attention(p, x, kv_embeds, cfg, *, policy=None):
    """Cross-attention onto (precomputed) modality embeddings."""
    b, s, _ = x.shape
    t = kv_embeds.shape[1]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(pmatmul(x, p["wq"], "attn_qkv", policy), nh, hd)
    k = _split_heads(pmatmul(kv_embeds, p["wk"], "attn_qkv", policy), nkv, hd)
    v = _split_heads(pmatmul(kv_embeds, p["wv"], "attn_qkv", policy), nkv, hd)
    group = nh // nkv
    q = q.reshape(b, s, nkv, group, hd)
    logits = jnp.einsum("bsngh,btnh->bnsgt", q, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnsgt,btnh->bsngh", probs, v,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, s, nh * hd)
    out = pmatmul(out, p["wo"], "attn_out", policy)
    return jnp.tanh(p["gate"]) * out


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], d_model, d_ff, dtype),
        "w_up": init_dense(ks[1], d_model, d_ff, dtype),
        "w_down": init_dense(ks[2], d_ff, d_model, dtype),
    }


def mlp(p, x, *, policy=None):
    """SwiGLU feed-forward."""
    g = pmatmul(x, p["w_gate"], "mlp_in", policy)
    u = pmatmul(x, p["w_up"], "mlp_in", policy)
    h = jax.nn.silu(g) * u
    h = constrain(h, "batch", "seq", "ffn")
    return pmatmul(h, p["w_down"], "mlp_out", policy)
