"""Mamba2 block (SSD — state space dual), built on the shared chunkwise
linear-attention core (ssm.py): scalar-per-head decay a_t = exp(dt * A),
B/C play the roles of k/q, dt-scaled x the role of v.

Includes the depthwise causal conv (kernel ssm_conv) with a rolling conv
state for decode.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers as L
from .policy import pmatmul
from .ssm import SSMState, chunked_linear_attention, linear_attention_step

__all__ = ["init_mamba2", "mamba2_block", "mamba2_step", "Mamba2State"]


class Mamba2State(NamedTuple):
    ssm: SSMState          # (b, h, d_state, head_dim)
    conv: jnp.ndarray      # (b, conv-1, conv_channels)


def _conv_channels(cfg):
    di = cfg.ssm_expand * cfg.d_model
    return di + 2 * cfg.ssm_state * cfg.n_heads


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 5)
    cc = _conv_channels(cfg)
    return {
        # in_proj -> [z (gate, di), xBC (conv channels), dt (h)]
        "w_in": L.init_dense(ks[0], d, di + cc + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, cc), jnp.float32)
                   * (1.0 / cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((cc,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": L.init_norm(di, dtype),
        "w_out": L.init_dense(ks[2], di, d, dtype),
    }


def _split_in(p, x, cfg, policy):
    di = cfg.ssm_expand * cfg.d_model
    cc = _conv_channels(cfg)
    h = cfg.n_heads
    proj = pmatmul(x, p["w_in"], "mlp_in", policy)
    z = proj[..., :di]
    xbc = proj[..., di:di + cc]
    dt = proj[..., di + cc:]
    return z, xbc, dt, di, cc, h


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv along seq. xbc: (b, t, c); w: (k, c)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)              # (b, t+k-1, c)
    out = sum(full[:, i:full.shape[1] - (k - 1 - i)] * w[i][None, None, :]
              for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out + b[None, None, :]), new_state


def _ssd_qkv(xbc, dt, p, cfg):
    b, t, _ = xbc.shape
    h, ds = cfg.n_heads, cfg.ssm_state
    di = cfg.ssm_expand * cfg.d_model
    hd = di // h
    xs = xbc[..., :di].reshape(b, t, h, hd)
    bmat = xbc[..., di:di + h * ds].reshape(b, t, h, ds)
    cmat = xbc[..., di + h * ds:].reshape(b, t, h, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b, t, h)
    a = -jnp.exp(p["a_log"])                                      # (h,)
    log_decay = dt * a[None, None, :]                             # <= 0
    v = xs.astype(jnp.float32) * dt[..., None]                    # dt-scaled input
    return (cmat.astype(jnp.float32), bmat.astype(jnp.float32), v,
            log_decay, xs, hd)


def mamba2_block(p, x, cfg, *, policy=None, chunk=256, state=None):
    b, t, d = x.shape
    z, xbc, dt, di, cc, h = _split_in(p, x, cfg, policy)
    conv_state = state.conv if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    q, k, v, log_a, xs, hd = _ssd_qkv(xbc, dt, p, cfg)
    y, new_ssm = chunked_linear_attention(
        q, k, v, log_a, chunk=min(chunk, max(t, 16)),
        init_state=state.ssm if state is not None else None, normalize=False)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = pmatmul(y, p["w_out"], "mlp_out", policy)
    return out, Mamba2State(new_ssm, new_conv)


def mamba2_step(p, x, cfg, state: Mamba2State, *, policy=None):
    """Decode: x (b, 1, d)."""
    b = x.shape[0]
    z, xbc, dt, di, cc, h = _split_in(p, x, cfg, policy)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    q, k, v, log_a, xs, hd = _ssd_qkv(xbc, dt, p, cfg)
    new_ssm, y = linear_attention_step(
        state.ssm, q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], normalize=False)
    y = y + xs[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = pmatmul(y, p["w_out"], "mlp_out", policy)
    return out, Mamba2State(new_ssm, new_conv)


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32):
    h, ds = cfg.n_heads, cfg.ssm_state
    di = cfg.ssm_expand * cfg.d_model
    hd = di // h
    cc = _conv_channels(cfg)
    return Mamba2State(
        SSMState(jnp.zeros((batch, h, ds, hd), jnp.float32),
                 jnp.zeros((batch, h, ds), jnp.float32)),
        jnp.zeros((batch, cfg.ssm_conv - 1, cc), dtype),
    )
