"""Family dispatch: one API over all ten assigned architectures.

  init_params(cfg, key)                     -> param pytree
  train_loss(params, cfg, batch)            -> scalar loss (+ aux)
  forward_logits(params, cfg, batch)        -> logits (prefill / encode)
  init_decode_state(cfg, batch, max_len)    -> cache pytree
  decode_step(params, cfg, state, tok, pos) -> (logits, state)
  input_specs(cfg, shape)                   -> ShapeDtypeStruct batch for dryrun
  param_logical_axes(cfg, params)           -> logical-axis names pytree

Families:
  dense          stacked scanned transformer blocks
  moe            transformer w/ MoE FFN every layer (+ shared experts)
  ssm (xlstm)    mLSTM blocks w/ sLSTM every cfg.slstm_every (scan groups)
  hybrid (zamba) Mamba2 blocks w/ ONE shared attn+mlp block every attn_every
  vlm            dense + cross-attention every cross_attn_every (stub images)
  audio          encoder-only (stub frame embeddings), CE over units
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L
from . import mamba2 as M2
from . import moe as MOE
from . import ssm as SSM
from . import transformer as TF
from .policy import pmatmul

__all__ = [
    "init_params", "train_loss", "forward_logits", "init_decode_state",
    "decode_step", "input_specs", "param_logical_axes",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg, key, dtype=jnp.float32):
    if cfg.family in ("dense", "audio"):
        return TF.init_params(cfg, key, dtype)
    if cfg.family == "moe":
        return _moe_init(cfg, key, dtype)
    if cfg.family == "ssm":
        return _xlstm_init(cfg, key, dtype)
    if cfg.family == "hybrid":
        return _zamba_init(cfg, key, dtype)
    if cfg.family == "vlm":
        return _vlm_init(cfg, key, dtype)
    raise ValueError(cfg.family)


def _moe_init(cfg, key, dtype):
    keys = jax.random.split(key, cfg.n_layers + 2)

    def block(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": L.init_norm(cfg.d_model, dtype),
            "attn": L.init_attention(k1, cfg, dtype=dtype),
            "mlp_norm": L.init_norm(cfg.d_model, dtype),
            "moe": MOE.init_moe(k2, cfg, dtype),
        }

    blocks = [block(keys[i]) for i in range(cfg.n_layers)]
    return {
        "embed": L.init_dense(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": L.init_norm(cfg.d_model, dtype),
        "lm_head": L.init_dense(keys[-1], cfg.d_model, cfg.vocab_size, dtype),
    }


def _xlstm_init(cfg, key, dtype):
    n_groups = cfg.n_layers // cfg.slstm_every
    keys = jax.random.split(key, n_groups + 2)

    def group(k):
        ks = jax.random.split(k, cfg.slstm_every)
        mblocks = [
            {"norm": L.init_norm(cfg.d_model, dtype),
             "mlstm": SSM.init_mlstm(ks[i], cfg, dtype)}
            for i in range(cfg.slstm_every - 1)
        ]
        return {
            "mlstm_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *mblocks),
            "slstm_norm": L.init_norm(cfg.d_model, dtype),
            "slstm": SSM.init_slstm(ks[-1], cfg, dtype),
        }

    groups = [group(keys[i]) for i in range(n_groups)]
    return {
        "embed": L.init_dense(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "final_norm": L.init_norm(cfg.d_model, dtype),
        "lm_head": L.init_dense(keys[-1], cfg.d_model, cfg.vocab_size, dtype),
    }


def _zamba_init(cfg, key, dtype):
    n_groups = cfg.n_layers // cfg.attn_every
    keys = jax.random.split(key, n_groups + 3)

    def group(k):
        ks = jax.random.split(k, cfg.attn_every)
        mb = [
            {"norm": L.init_norm(cfg.d_model, dtype),
             "mamba": M2.init_mamba2(ks[i], cfg, dtype)}
            for i in range(cfg.attn_every)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *mb)

    groups = [group(keys[i]) for i in range(n_groups)]
    k1, k2 = jax.random.split(keys[-3])
    return {
        "embed": L.init_dense(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        # ONE shared transformer block applied after every group
        "shared": TF.init_block(k1, cfg, dtype),
        "final_norm": L.init_norm(cfg.d_model, dtype),
        "lm_head": L.init_dense(keys[-1], cfg.d_model, cfg.vocab_size, dtype),
    }


def _vlm_init(cfg, key, dtype):
    n_groups = cfg.n_layers // cfg.cross_attn_every
    keys = jax.random.split(key, n_groups + 2)

    def group(k):
        ks = jax.random.split(k, cfg.cross_attn_every + 1)
        blocks = [TF.init_block(ks[i], cfg, dtype)
                  for i in range(cfg.cross_attn_every)]
        return {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "xattn_norm": L.init_norm(cfg.d_model, dtype),
            "xattn": L.init_cross_attention(ks[-1], cfg, dtype),
        }

    groups = [group(keys[i]) for i in range(n_groups)]
    return {
        "embed": L.init_dense(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "final_norm": L.init_norm(cfg.d_model, dtype),
        "lm_head": L.init_dense(keys[-1], cfg.d_model, cfg.vocab_size, dtype),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill / encode)
# ---------------------------------------------------------------------------


def _remat(fn, mode):
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward_logits(params, cfg, batch, *, policy=None, remat: str = "none"):
    """batch: dict with 'tokens' (b, s) [or 'features' for audio] and

    optionally 'image_embeds' (b, n_img, d) for vlm.  Returns (logits, aux).
    """
    aux = jnp.float32(0.0)
    if cfg.family in ("dense",):
        return TF.forward(params, cfg, batch["tokens"], policy=policy,
                          remat=remat), aux
    if cfg.family == "audio":
        x = batch["features"]  # (b, s, d) stub frame embeddings
        return TF.forward(params, cfg, x, policy=policy, remat=remat,
                          causal=False), aux
    if cfg.family == "moe":
        return _moe_forward(params, cfg, batch["tokens"], policy, remat)
    if cfg.family == "ssm":
        return _xlstm_forward(params, cfg, batch["tokens"], policy, remat), aux
    if cfg.family == "hybrid":
        return _zamba_forward(params, cfg, batch["tokens"], policy, remat), aux
    if cfg.family == "vlm":
        return _vlm_forward(params, cfg, batch["tokens"],
                            batch["image_embeds"], policy, remat), aux
    raise ValueError(cfg.family)


def _moe_forward(params, cfg, tokens, policy, remat):
    b, s = tokens.shape
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, block):
        x, aux = carry
        h, _ = L.attention(
            block["attn"], L.rmsnorm(x, block["attn_norm"], cfg.norm_eps), cfg,
            positions=positions, policy=policy)
        x = x + h
        mo, a = MOE.moe_layer(
            block["moe"], L.rmsnorm(x, block["mlp_norm"], cfg.norm_eps), cfg,
            policy=policy)
        return (x + mo, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(body, remat),
                               (x, jnp.float32(0.0)), params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return TF.unembed(params, cfg, x, policy), aux / cfg.n_layers


def _xlstm_forward(params, cfg, tokens, policy, remat):
    x = TF.embed_tokens(params, cfg, tokens, policy)

    def group_body(x, group):
        def mblock(x, blk):
            h, _ = SSM.mlstm_block(
                blk["mlstm"], L.rmsnorm(x, blk["norm"], cfg.norm_eps), cfg,
                policy=policy)
            return x + h, None

        x, _ = jax.lax.scan(mblock, x, group["mlstm_blocks"])
        h, _ = SSM.slstm_block(
            params_group_slstm(group), L.rmsnorm(x, group["slstm_norm"], cfg.norm_eps),
            cfg, policy=policy)
        return x + h, None

    def params_group_slstm(group):
        return group["slstm"]

    x, _ = jax.lax.scan(_remat(group_body, remat), x, params["groups"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return TF.unembed(params, cfg, x, policy)


def _zamba_forward(params, cfg, tokens, policy, remat):
    b, s = tokens.shape
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    shared = params["shared"]

    def group_body(x, group):
        def mblock(x, blk):
            h, _ = M2.mamba2_block(
                blk["mamba"], L.rmsnorm(x, blk["norm"], cfg.norm_eps), cfg,
                policy=policy)
            return x + h, None

        x, _ = jax.lax.scan(mblock, x, group)
        # shared attention block (same params every group: zamba2)
        x = TF._block_apply(cfg, policy, shared, x, positions=positions,
                            mask=None, cache=None, cache_pos=None,
                            causal=True)[0]
        return x, None

    x, _ = jax.lax.scan(_remat(group_body, remat), x, params["groups"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return TF.unembed(params, cfg, x, policy)


def _vlm_forward(params, cfg, tokens, image_embeds, policy, remat):
    b, s = tokens.shape
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def group_body(x, group):
        def block(x, blk):
            return TF._block_apply(cfg, policy, blk, x, positions=positions,
                                   mask=None, cache=None, cache_pos=None,
                                   causal=True)[0], None

        x, _ = jax.lax.scan(block, x, group["blocks"])
        h = L.cross_attention(
            group["xattn"], L.rmsnorm(x, group["xattn_norm"], cfg.norm_eps),
            image_embeds, cfg, policy=policy)
        return x + h, None

    x, _ = jax.lax.scan(_remat(group_body, remat), x, params["groups"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return TF.unembed(params, cfg, x, policy)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def train_loss(params, cfg, batch, *, policy=None, remat: str = "none",
               aux_weight: float = 0.01):
    logits, aux = forward_logits(params, cfg, batch, policy=policy, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(ll))
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe"):
        return TF.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "vlm":
        return TF.init_cache(cfg, batch, max_len, dtype)  # self-attn caches
    if cfg.family == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.n_heads
        hd = di // h
        return {
            "mlstm": SSM.SSMState(
                jnp.zeros((n_groups, cfg.slstm_every - 1, batch, h, hd, hd), jnp.float32),
                jnp.zeros((n_groups, cfg.slstm_every - 1, batch, h, hd), jnp.float32)),
            "slstm": tuple(
                jnp.zeros((n_groups, batch, di), jnp.float32) for _ in range(3)),
        }
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        base = M2.init_mamba2_state(cfg, batch)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None, None], (n_groups, cfg.attn_every) + x.shape), base)
        kv = TF.init_cache(cfg, batch, max_len, dtype)
        shared_kv = L.KVCache(kv.k[:n_groups], kv.v[:n_groups])
        return {"mamba": stacked, "shared_kv": shared_kv}
    raise ValueError(f"{cfg.family} does not support decode")


def decode_step(params, cfg, state, tokens, pos, *, policy=None):
    """tokens (b, 1), pos scalar -> (logits (b, vocab), new state)."""
    if cfg.family == "dense":
        return TF.decode_step(params, cfg, state, tokens, pos, policy=policy)
    if cfg.family == "moe":
        return _moe_decode(params, cfg, state, tokens, pos, policy)
    if cfg.family == "ssm":
        return _xlstm_decode(params, cfg, state, tokens, pos, policy)
    if cfg.family == "hybrid":
        return _zamba_decode(params, cfg, state, tokens, pos, policy)
    if cfg.family == "vlm":
        return _vlm_decode(params, cfg, state, tokens, pos, policy)
    raise ValueError(f"{cfg.family} does not support decode")


def _moe_decode(params, cfg, cache, tokens, pos, policy):
    b = tokens.shape[0]
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    def body(x, blk_cache):
        block, (k, v) = blk_cache
        h, new_c = L.attention(
            block["attn"], L.rmsnorm(x, block["attn_norm"], cfg.norm_eps), cfg,
            positions=positions, cache=L.KVCache(k, v), cache_pos=pos,
            causal=False, policy=policy)
        x = x + h
        mo, _ = MOE.moe_layer(
            block["moe"], L.rmsnorm(x, block["mlp_norm"], cfg.norm_eps), cfg,
            policy=policy)
        return x + mo, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], tuple(cache)))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return TF.unembed(params, cfg, x, policy)[:, 0], L.KVCache(*new_cache)


def _xlstm_decode(params, cfg, state, tokens, pos, policy):
    x = TF.embed_tokens(params, cfg, tokens, policy)

    def group_body(x, scans):
        group, m_state, s_state = scans

        def mblock(x, blk_state):
            blk, st = blk_state
            h, new_st = SSM.mlstm_step(
                blk["mlstm"], L.rmsnorm(x, blk["norm"], cfg.norm_eps), cfg,
                SSM.SSMState(*st), policy=policy)
            return x + h, tuple(new_st)

        x, new_m = jax.lax.scan(mblock, x,
                                (group["mlstm_blocks"], tuple(m_state)))
        h, new_s = SSM.slstm_step(
            group["slstm"], L.rmsnorm(x, group["slstm_norm"], cfg.norm_eps),
            cfg, s_state, policy=policy)
        return x + h, (new_m, new_s)

    x, (new_m, new_s) = jax.lax.scan(
        group_body, x,
        (params["groups"], tuple(state["mlstm"]), state["slstm"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = TF.unembed(params, cfg, x, policy)
    return logits[:, 0], {"mlstm": SSM.SSMState(*new_m), "slstm": new_s}


def _zamba_decode(params, cfg, state, tokens, pos, policy):
    b = tokens.shape[0]
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    shared = params["shared"]

    def group_body(x, scans):
        group, m_state, (k, v) = scans

        def mblock(x, blk_state):
            blk, st = blk_state
            h, new_st = M2.mamba2_step(
                blk["mamba"], L.rmsnorm(x, blk["norm"], cfg.norm_eps), cfg,
                M2.Mamba2State(SSM.SSMState(st[0], st[1]), st[2]),
                policy=policy)
            return x + h, (new_st.ssm.s, new_st.ssm.n, new_st.conv)

        x, new_m = jax.lax.scan(
            mblock, x,
            (group, (m_state.ssm.s, m_state.ssm.n, m_state.conv)))
        x, new_kv = TF._block_apply(
            cfg, policy, shared, x, positions=positions, mask=None,
            cache=L.KVCache(k, v), cache_pos=pos, causal=False)
        return x, (new_m, new_kv)

    x, (new_m, new_kv) = jax.lax.scan(
        group_body, x,
        (params["groups"],
         state["mamba"],
         tuple(state["shared_kv"])))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = TF.unembed(params, cfg, x, policy)
    new_mamba = M2.Mamba2State(SSM.SSMState(new_m[0], new_m[1]), new_m[2])
    return logits[:, 0], {"mamba": new_mamba, "shared_kv": L.KVCache(*new_kv)}


def _vlm_decode(params, cfg, cache, tokens, pos, policy):
    # decode attends to text KV caches only (image context is baked into
    # the caches during prefill; the cross-attn contribution at decode uses
    # the stub embeddings statically — simplification documented)
    b = tokens.shape[0]
    x = TF.embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    n_per = cfg.cross_attn_every
    n_groups = cfg.n_layers // n_per
    k_all, v_all = cache
    # reshape layer-stacked cache into groups
    kg = k_all.reshape(n_groups, n_per, *k_all.shape[1:])
    vg = v_all.reshape(n_groups, n_per, *v_all.shape[1:])

    def group_body(x, scans):
        group, kk, vv = scans

        def block(x, blk_kv):
            blk, (k, v) = blk_kv
            x, new_c = TF._block_apply(cfg, policy, blk, x,
                                       positions=positions, mask=None,
                                       cache=L.KVCache(k, v), cache_pos=pos,
                                       causal=False)
            return x, new_c

        x, new_kv = jax.lax.scan(block, x, (group["blocks"], (kk, vv)))
        return x, new_kv

    x, (nk, nv) = jax.lax.scan(group_body, x, (params["groups"], kg, vg))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = TF.unembed(params, cfg, x, policy)
    new_cache = L.KVCache(nk.reshape(k_all.shape), nv.reshape(v_all.shape))
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins) + logical axes
# ---------------------------------------------------------------------------


def input_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {
                "features": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_modality_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    return batch


def param_logical_axes(cfg, params):
    """Logical axis names per parameter leaf (for sharding rules)."""

    def axes_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = names[-1]
        nd = leaf.ndim
        stacked = ("layers" in names or "groups" in names or
                   "mlstm_blocks" in names or "blocks" in names)
        lead = ["layers"] * (nd - 2) if stacked else []
        # normalization / bias vectors
        if nd - len(lead) == 1:
            return tuple(lead + ["norm"])
        if name == "embed":
            return tuple(lead + ["vocab", "embed"])
        if name == "lm_head":
            return tuple(lead + ["embed", "vocab"])
        if name in ("wq", "wk", "wv", "w_in", "w_up", "w_gate", "w_if"):
            return tuple(lead + ["embed", "heads"])
        if name in ("wo", "w_down", "w_out"):
            return tuple(lead + ["heads", "embed"])
        if name == "router":
            return tuple(lead + ["embed", None])
        if name == "conv_w":
            return tuple(lead + ["conv", None])
        if name == "r":
            return tuple(lead + [None, None])
        return tuple(lead + [None] * (nd - len(lead)))

    def axes_for_moe(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = names[-1]
        if name in ("w_gate", "w_up", "w_down") and "moe" in names:
            lead = ["layers"] * (leaf.ndim - 3)
            return tuple(lead + ["experts", "embed" if name != "w_down" else "expert_ffn",
                                 "expert_ffn" if name != "w_down" else "embed"])
        return axes_for(path, leaf)

    return jax.tree_util.tree_map_with_path(
        axes_for_moe if cfg.family == "moe" else axes_for, params)
