"""Mixture-of-Experts layer: token-choice routing with per-expert capacity.

Routing: softmax gate -> top-k experts per token; each expert then keeps its
top-C tokens by gate weight (capacity C = tokens * k / E * capacity_factor),
dropping overflow (standard capacity-based dropping MoE).  Dispatch/combine
are gather/scatter-add over (E, C) index tables — no (T, E, C) one-hot
tensors, so the memory footprint is O(E*C*d) and shards cleanly: experts
(and the (E, C, d) dispatch buffers) ride the "model"/EP axis, tokens the
"data" axis.  Under GSPMD the dispatch gather lowers to the expert-parallel
all-to-all-equivalent collective; see EXPERIMENTS.md §Perf for the measured
collective cost and the shard_map alternative.

Router logits run in f32 by default (policy site "router") — low-precision
routers are a known training instability; with the paper's engine the site
can be pushed to binary128-class ("dd") for bitwise-reproducible routing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L
from .policy import pmatmul

__all__ = ["init_moe", "moe_layer"]


def init_moe(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    p = {
        "router": L.init_dense(ks[0], d, e, dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, cfg.d_ff * cfg.n_shared_experts, dtype)
    return p


def moe_layer(p, x, cfg, *, policy=None):
    """x: (batch, seq, d). Returns (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(t, d)

    logits = pmatmul(xf, p["router"], "router", policy)        # (t, e) f32
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)                   # (t, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style); pin f32 (one_hot defaults to
    # f64 when x64 is enabled, which breaks scan carry dtypes)
    density = jnp.mean(jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0)
    mean_probs = probs.mean(axis=0)
    aux_loss = (e * jnp.sum(density * mean_probs)).astype(jnp.float32)

    # per-(token, expert) weight table, then per-expert top-C capacity
    cap = int(max(1, (t * k) / e * cfg.capacity_factor))
    weights_te = jax.vmap(
        lambda w, i: jnp.zeros((e,), probs.dtype).at[i].set(w)
    )(top_w, top_idx)                                          # (t, e) sparse-dense

    ew = weights_te.T                                          # (e, t)
    cap_w, cap_idx = jax.lax.top_k(ew, cap)                    # (e, cap)
    keep = cap_w > 0

    # dispatch: gather tokens to (e, cap, d) expert buffers
    disp = xf[cap_idx]                                         # (e, cap, d)
    disp = constrain(disp, "experts", None, None)
    h_g = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    h_u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(h_g) * h_u
    h = constrain(h, "experts", None, None)
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # combine: weighted scatter-add back to token order
    w_keep = jnp.where(keep, cap_w, 0.0).astype(x.dtype)       # (e, cap)
    contrib = y_e * w_keep[..., None]
    out = jnp.zeros((t, d), x.dtype).at[cap_idx.reshape(-1)].add(
        contrib.reshape(-1, d))
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + L.mlp(p["shared"], x, policy=policy)
    return out, aux_loss
