"""Precision policy: the paper's technique as a first-class model feature.

Every dense projection in the model stack calls ``pmatmul(x, w, site,
policy)``.  A policy maps site names to precision modes:

  native — matmul in the parameter dtype with f32 accumulation (default)
  f32    — operands cast to f32 (e.g. router logits, a known MoE
           instability)
  dd     — binary128-class GEMM via the Ozaki engine (core/ozaki.py):
           operands are promoted to double-word, the product is computed
           with error-free slice GEMMs, and the result is returned in f32.
           Gradients flow through a straight-through f32 VJP (the extended
           precision is a forward-accuracy feature: logit/loss drift kills
           long-run reproducibility, not gradient quality).

Sites: attn_qkv, attn_out, mlp_in, mlp_out, router, lm_head, embed.
"""

from __future__ import annotations

import functools
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

__all__ = ["pmatmul", "PrecisionPolicy", "DEFAULT_POLICY"]

PrecisionPolicy = Mapping[str, str]

DEFAULT_POLICY: dict = {}  # empty -> native everywhere


@jax.custom_vjp
def _dd_matmul_st(x32, w32):
    """f32 matmul computed through the binary128-class Ozaki engine."""
    from repro.core import dd, ozaki

    xdd = dd.from_float(x32.astype(jnp.float64))
    wdd = dd.from_float(w32.astype(jnp.float64))
    out = ozaki.ozaki_gemm(xdd, wdd)
    return dd.to_float(out).astype(jnp.float32)


def _dd_fwd(x32, w32):
    return _dd_matmul_st(x32, w32), (x32, w32)


def _dd_bwd(res, g):
    x32, w32 = res
    return (g @ w32.T, x32.T @ g)


_dd_matmul_st.defvjp(_dd_fwd, _dd_bwd)


def pmatmul(x, w, site: str, policy: Optional[PrecisionPolicy] = None):
    """Dense projection with per-site precision selection.

    x: (..., d_in), w: (d_in, d_out).
    """
    mode = (policy or DEFAULT_POLICY).get(site, "native")
    if mode == "native":
        return jnp.einsum("...d,df->...f", x, w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if mode == "f32":
        return jnp.einsum("...d,df->...f", x.astype(jnp.float32),
                          w.astype(jnp.float32))
    if mode == "dd":
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        out = _dd_matmul_st(x2, w.astype(jnp.float32))
        return out.reshape(*lead, w.shape[-1])
    raise ValueError(f"unknown precision mode {mode!r} for site {site!r}")
