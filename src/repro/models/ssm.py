"""Linear-recurrent sequence mixing: a chunkwise core shared by mLSTM (xLSTM)
and Mamba2 (SSD) — both are gated linear attention with per-step scalar decay:

    S_t = a_t * S_{t-1} + k_t v_t^T          (state (d_k, d_v) per head)
    y_t = q_t^T S_t

The chunkwise-parallel form splits the sequence into chunks: within a chunk
a masked decay-weighted attention matrix (quadratic in chunk size), across
chunks a lax.scan carries the state — O(T * chunk) work, O(T/chunk) scan
steps, and O(1) state for decode.  This is the sub-quadratic path that makes
the 500k-token cells runnable (DESIGN.md §5).

mLSTM here is the stabilized-lite variant: exponential input gate folded
into a per-chunk max-normalizer, sigmoid forget gate, q/k/v heads + RMS
output norm (simplifications documented in DESIGN.md).  sLSTM blocks use a
per-timestep lax.scan recurrence (block-diagonal per head).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L
from .policy import pmatmul

__all__ = [
    "chunked_linear_attention",
    "linear_attention_step",
    "init_mlstm",
    "mlstm_block",
    "mlstm_step",
    "init_slstm",
    "slstm_block",
    "slstm_step",
    "SSMState",
]


class SSMState(NamedTuple):
    s: jnp.ndarray  # (batch, heads, d_k, d_v) matrix memory
    n: jnp.ndarray  # (batch, heads, d_k) normalizer memory


def chunked_linear_attention(q, k, v, log_a, *, chunk: int = 256,
                             init_state: SSMState | None = None,
                             normalize: bool = True):
    """Gated linear attention, chunkwise-parallel.

    q, k, v: (b, t, h, d_k/d_k/d_v); log_a: (b, t, h) per-step log decay
    (<= 0).  Returns (y (b, t, h, d_v), final SSMState).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        q, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) for x in (q, k, v))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    tp = q.shape[1]
    nc = tp // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:])

    qc, kc, vc, lac = map(to_chunks, (q, k, v, log_a))
    # cumulative decay within chunk: A_i = sum_{j<=i} log_a_j
    cum = jnp.cumsum(lac, axis=2)                      # (b, nc, c, h)
    total = cum[:, :, -1:, :]                          # (b, nc, 1, h)

    s0 = init_state.s if init_state is not None else \
        jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = init_state.n if init_state is not None else \
        jnp.zeros((b, h, dk), jnp.float32)

    def chunk_step(carry, xs):
        s, n = carry                                   # (b,h,dk,dv), (b,h,dk)
        qi, ki, vi, cumi, toti = xs                    # (b,c,h,*)
        # intra-chunk: masked decay attention
        # decay from j to i: exp(cum_i - cum_j), j <= i.  Mask BEFORE the
        # exp: where(mask, exp(pos_big), 0) still back-propagates NaN from
        # the inf forward value (observed on zamba2 grads).
        dmat = cumi[:, :, None, :] - cumi[:, None, :, :]      # (b, c, c, h)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -1e30)
        w = jnp.exp(dmat)
        att = jnp.einsum("bihd,bjhd->bijh", qi, ki) * w       # (b,c,c,h)
        y_intra = jnp.einsum("bijh,bjhe->bihe", att, vi)
        # inter-chunk: contribution of carried state
        qdec = qi * jnp.exp(cumi)[..., None]                  # (b,c,h,dk)
        y_inter = jnp.einsum("bchd,bhde->bche", qdec, s)
        y = y_intra + y_inter
        if normalize:
            # normalizer q.n: the intra part is the att row-sum
            n_inter = jnp.einsum("bchd,bhd->bch", qdec, n)
            denom = jnp.abs(att.sum(axis=2) + n_inter)
            y = y / jnp.maximum(denom, 1.0)[..., None]
        # state update: S' = a_total * S + sum_j exp(total - cum_j) k_j v_j^T
        kdec = ki * jnp.exp(toti - cumi)[..., None]           # (b,c,h,dk)
        s_new = jnp.exp(toti[:, -1])[..., None, None] * s + \
            jnp.einsum("bchd,bche->bhde", kdec, vi)
        n_new = jnp.exp(toti[:, -1])[..., None] * n + kdec.sum(axis=1)
        return (s_new, n_new), y

    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(cum, 1, 0), jnp.moveaxis(total, 1, 0),
    )
    (s_f, n_f), ys = jax.lax.scan(chunk_step, (s0, n0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tp, h, dv)[:, :t]
    return y, SSMState(s_f, n_f)


def linear_attention_step(state: SSMState, q, k, v, log_a, *, normalize=True):
    """Single-token recurrent step (decode). q/k/v: (b, h, d); log_a: (b, h)."""
    a = jnp.exp(log_a)                                 # (b, h)
    s = a[..., None, None] * state.s + k[..., :, None] * v[..., None, :]
    n = a[..., None] * state.n + k
    y = jnp.einsum("bhd,bhde->bhe", q, s)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return SSMState(s, n), y


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": L.init_dense(ks[0], d, 2 * di, dtype),     # x and gate paths
        "wq": L.init_dense(ks[1], di, di, dtype),
        "wk": L.init_dense(ks[2], di, di, dtype),
        "wv": L.init_dense(ks[3], di, di, dtype),
        "w_if": L.init_dense(ks[4], di, 2 * h, dtype),     # input+forget gates
        "out_norm": L.init_norm(di, dtype),
        "w_down": L.init_dense(ks[5], di, d, dtype),
    }


def _mlstm_qkv(p, x, cfg, policy):
    b, t, _ = x.shape
    h = cfg.n_heads
    di = cfg.ssm_expand * cfg.d_model
    hd = di // h
    up = pmatmul(x, p["w_up"], "mlp_in", policy)
    xin, gate = jnp.split(up, 2, axis=-1)
    q = pmatmul(xin, p["wq"], "attn_qkv", policy).reshape(b, t, h, hd)
    k = pmatmul(xin, p["wk"], "attn_qkv", policy).reshape(b, t, h, hd) * hd ** -0.5
    v = pmatmul(xin, p["wv"], "attn_qkv", policy).reshape(b, t, h, hd)
    gates = pmatmul(xin, p["w_if"], "attn_qkv", policy).astype(jnp.float32)
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)      # (b, t, h)
    log_a = jax.nn.log_sigmoid(f_gate)
    k = k * jnp.exp(jnp.minimum(i_gate, 0.0))[..., None]  # bounded input gate
    return q, k, v, log_a, gate, di, hd


def mlstm_block(p, x, cfg, *, policy=None, chunk=256, state=None):
    """x: (b, t, d) -> (b, t, d); parallel (train/prefill) form."""
    b, t, _ = x.shape
    q, k, v, log_a, gate, di, hd = _mlstm_qkv(p, x, cfg, policy)
    y, new_state = chunked_linear_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_a, chunk=min(chunk, max(t, 16)), init_state=state)
    y = y.reshape(b, t, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(gate)
    return pmatmul(y, p["w_down"], "mlp_out", policy), new_state


def mlstm_step(p, x, cfg, state: SSMState, *, policy=None):
    """x: (b, 1, d) decode step."""
    b = x.shape[0]
    q, k, v, log_a, gate, di, hd = _mlstm_qkv(p, x, cfg, policy)
    new_state, y = linear_attention_step(
        state, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), log_a[:, 0])
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(gate)
    return pmatmul(y, p["w_down"], "mlp_out", policy), new_state


# ---------------------------------------------------------------------------
# sLSTM block (scalar recurrence per head)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ks = jax.random.split(key, 4)
    return {
        "w_in": L.init_dense(ks[0], d, 4 * di, dtype),   # z, i, f, o pre-acts
        "r": (jax.random.normal(ks[1], (4, di), jnp.float32) * 0.1).astype(dtype),
        "out_norm": L.init_norm(di, dtype),
        "w_down": L.init_dense(ks[2], di, d, dtype),
    }


def _slstm_scan(pre, r, h0, c0, n0):
    """pre: (b, t, 4, di) preactivations; diagonal recurrence weights r."""

    def step(carry, x_t):
        h, c, n = carry
        z, i, f, o = (x_t[:, j] + r[j][None, :] * h for j in range(4))
        i = jnp.exp(jnp.minimum(i, 0.0))
        f = jax.nn.sigmoid(f)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (h, c, n), h

    (h, c, n), hs = jax.lax.scan(step, (h0, c0, n0), jnp.moveaxis(pre, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (h, c, n)


def slstm_block(p, x, cfg, *, policy=None, state=None):
    b, t, d = x.shape
    di = cfg.ssm_expand * d
    pre = pmatmul(x, p["w_in"], "mlp_in", policy).astype(jnp.float32)
    pre = pre.reshape(b, t, 4, di)
    if state is None:
        z = jnp.zeros((b, di), jnp.float32)
        state = (z, z, z)
    hs, new_state = _slstm_scan(pre, p["r"].astype(jnp.float32), *state)
    y = L.rmsnorm(hs.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    return pmatmul(y, p["w_down"], "mlp_out", policy), new_state


def slstm_step(p, x, cfg, state, *, policy=None):
    y, new_state = slstm_block(p, x, cfg, policy=policy, state=state)
    return y, new_state
