"""Dense decoder LM (qwen3 / mistral / llama families) + encoder variant.

Layers are weight-stacked and scanned (jax.lax.scan) so the HLO stays
compact at 126 layers; remat policy applies per scanned block.  Decode uses
per-layer KV caches stacked on a leading layer axis.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain
from . import layers as L
from .policy import pmatmul

__all__ = ["init_params", "forward", "init_cache", "decode_step"]


def _remat(fn, mode: str):
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def init_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.init_norm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg, dtype=dtype),
        "mlp_norm": L.init_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg, key, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [init_block(keys[i], cfg, dtype) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": L.init_dense(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.init_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[-1], cfg.d_model, cfg.vocab_size, dtype)
    return params


def _block_apply(cfg, policy, block, x, *, positions, mask, cache, cache_pos,
                 causal):
    if cache is None:
        # Megatron-SP: residual stream sequence-sharded over the TP axis —
        # the scan-remat saved activations shrink by the TP degree and the
        # norms deduplicate; GSPMD inserts the AG/RS pair at the block edge
        x = constrain(x, "batch", "seq_res", None)
    h, new_cache = L.attention(
        block["attn"], L.rmsnorm(x, block["attn_norm"], cfg.norm_eps), cfg,
        positions=positions, mask=mask, cache=cache, cache_pos=cache_pos,
        causal=causal, policy=policy)
    x = x + h
    x = x + L.mlp(block["mlp"], L.rmsnorm(x, block["mlp_norm"], cfg.norm_eps),
                  policy=policy)
    if cache is None:
        x = constrain(x, "batch", "seq_res", None)
    return x, new_cache


def embed_tokens(params, cfg, tokens, policy=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "batch", "seq", None)


def unembed(params, cfg, x, policy=None):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = pmatmul(x, w, "lm_head", policy)
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def forward(params, cfg, tokens, *, policy=None, remat: str = "none",
            positions=None, causal: Optional[bool] = None):
    """Full-sequence forward -> logits (train / prefill / encode)."""
    causal = (not cfg.encoder_only) if causal is None else causal
    b, s = tokens.shape[:2]
    if tokens.ndim == 2 and jnp.issubdtype(tokens.dtype, jnp.integer):
        x = embed_tokens(params, cfg, tokens, policy)
    else:
        # pre-embedded modality input (audio stub); match the param compute
        # dtype so the layer-scan carry type is stable
        x = tokens.astype(params["final_norm"].dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, block):
        x = _block_apply(cfg, policy, block, x, positions=positions,
                         mask=None, cache=None, cache_pos=None, causal=causal)[0]
        return x, None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x, policy)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return L.KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_step(params, cfg, cache: L.KVCache, tokens, pos, *, policy=None):
    """One decode step: tokens (b, 1), pos scalar int32 (current position).

    Returns (logits (b, vocab), new_cache).
    """
    b = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens, policy)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    def body(x, blk_and_cache):
        block, (k, v) = blk_and_cache
        x, new_c = _block_apply(cfg, policy, block, x, positions=positions,
                                mask=None, cache=L.KVCache(k, v),
                                cache_pos=pos, causal=False)
        return x, new_c

    x, new_caches = jax.lax.scan(body, x, (params["layers"], tuple(cache)))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x, policy)
    return logits[:, 0], L.KVCache(*new_caches)
