"""Optimizers: AdamW with int8 states and DD master-weight options."""

from .adamw import make_optimizer, OptState  # noqa: F401
