"""AdamW with production-scale state options.

  adamw       — f32 m/v states.
  adamw_int8  — block-quantized int8 m/v with per-block f32 scales
                (~6 bytes/param optimizer footprint instead of 8; the knob
                that lets llama3-405b train_4k fit 256 v5e chips, see
                EXPERIMENTS.md §Dry-run).
  adamw_dd    — double-word (df32) master weights: the paper's technique in
                the optimizer.  Updates accumulate in ~49-bit precision, so
                tiny late-training updates are not swallowed by f32 rounding
                (test_optim.py demonstrates the drift).

Schedule: linear warmup + cosine decay.  Global-norm clipping included.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "make_optimizer"]

_QBLOCK = 128


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master_lo: Any          # df32 master-weight low limbs (adamw_dd) or None


# Quantization is PER-ROW (last dim): no reshapes, so the quantized state
# keeps exactly the parameter's shape/sharding and GSPMD propagation is
# trivial (block-reshape variants replicated 1.6 TB of moments at 405B
# scale because shardings do not survive flatten/reshape).


def _quantize_int8(x):
    """Symmetric linear int8 with per-row scale (first moments)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_int8(q, scale, shape=None):
    del shape
    return q.astype(jnp.float32) * scale


def _quantize_int8_log(x):
    """Log-domain affine int8 for NON-NEGATIVE tensors (second moments).

    Linear quantization underflows small v entries to 0 in a row with a
    large max, and m/(sqrt(0)+eps) then explodes — relative precision must
    be uniform across magnitudes, i.e. quantize log2(v).  Scale meta packs
    (min, range) in a trailing dim of 2.
    """
    lx = jnp.log2(x + 1e-30)
    mn = jnp.min(lx, axis=-1, keepdims=True)
    rng = jnp.maximum(jnp.max(lx, axis=-1, keepdims=True) - mn, 1e-6)
    t = (lx - mn) / rng
    q = (jnp.round(t * 255.0) - 128.0).astype(jnp.int8)
    return q, jnp.concatenate([mn, rng], axis=-1).astype(jnp.float32)


def _dequantize_int8_log(q, meta, shape=None):
    del shape
    mn, rng = meta[..., :1], meta[..., 1:2]
    t = (q.astype(jnp.float32) + 128.0) / 255.0
    return jnp.maximum(jnp.exp2(mn + t * rng) - 1e-30, 0.0)


def schedule(step, cfg):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * factor).astype(g.dtype), grads), gn


def make_optimizer(run_cfg, constrain=None):
    """Returns (init_fn, update_fn) for run_cfg.optimizer.

    ``constrain``: optional callback applied to param-shaped f32 trees
    (dequantized moments).  Required at scale for adamw_int8: GSPMD cannot
    propagate shardings through the quantization reshapes ((nblocks, 128)
    <-> param shape), so the dequantized moments otherwise replicate — a
    1.6 TB/device temp for llama3-405b (observed before this fix).
    """
    kind = run_cfg.optimizer
    b1, b2, eps = 0.9, 0.95, 1e-8
    constrain = constrain or (lambda tree: tree)

    def init(params):
        if kind == "adamw_int8":
            m = jax.tree.map(
                lambda p: _quantize_int8(jnp.zeros_like(p, jnp.float32)), params)
            v = jax.tree.map(
                lambda p: _quantize_int8_log(jnp.zeros_like(p, jnp.float32)),
                params)
        else:
            m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            v = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        master_lo = None
        if kind == "adamw_dd":
            master_lo = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), m, v, master_lo)

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr = schedule(step.astype(jnp.float32), run_cfg)
        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        flat_p = tdef.flatten_up_to(params)

        def moments(g, m_q, v_q):
            g32 = g.astype(jnp.float32)
            if kind == "adamw_int8":
                m = _dequantize_int8(*m_q)
                v = _dequantize_int8_log(*v_q)
            else:
                m, v = m_q, v_q
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if kind == "adamw_int8":
                return upd, _quantize_int8(m), _quantize_int8_log(v)
            return upd, m, v

        def leaf_out(g, m_q, v_q):
            # layer-stacked leaves scan the update over the layer axis: the
            # f32 dequantize/update temps otherwise materialize whole-leaf
            # (4 x 1.7 GB/device per monster leaf at 405B scale)
            if kind == "adamw_int8" and g.ndim >= 3 and g.shape[0] >= 8:
                def body(_, xs):
                    return None, moments(*xs)

                _, (upd, nm, nv) = jax.lax.scan(body, None, (g, m_q, v_q))
                return upd, nm, nv
            return moments(g, m_q, v_q)

        outs = [leaf_out(g, m, v) for g, m, v in zip(flat_g, flat_m, flat_v)]
        upds = tdef.flatten_up_to(constrain(tdef.unflatten([o[0] for o in outs])))
        new_m = tdef.unflatten([o[1] for o in outs])
        new_v = tdef.unflatten([o[2] for o in outs])

        if kind == "adamw_dd":
            from repro.core.efts import quick_two_sum, two_sum

            flat_lo = tdef.flatten_up_to(state.master_lo)
            new_p, new_lo = [], []
            for p, lo, u in zip(flat_p, flat_lo, upds):
                delta = (-lr * (u + run_cfg.weight_decay * p.astype(jnp.float32))
                         ).astype(jnp.float32)
                # df32 accumulation: (p, lo) += delta, error-free
                s, e = two_sum(p.astype(jnp.float32), delta)
                e = e + lo
                hi, lo2 = quick_two_sum(s, e)
                new_p.append(hi.astype(p.dtype))
                new_lo.append(lo2)
            return (tdef.unflatten(new_p),
                    OptState(step, new_m, new_v, tdef.unflatten(new_lo)),
                    {"lr": lr, "gnorm": gnorm})

        new_p = [
            (p.astype(jnp.float32)
             - lr * (u + run_cfg.weight_decay * p.astype(jnp.float32))
             ).astype(p.dtype)
            for p, u in zip(flat_p, upds)
        ]
        return (tdef.unflatten(new_p), OptState(step, new_m, new_v, None),
                {"lr": lr, "gnorm": gnorm})

    return init, update
