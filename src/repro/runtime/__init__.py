"""Distributed runtime: sharding rules, collectives, failover, pipeline."""
