"""Distributed-optimization collectives.

compensated_psum — the paper's high-precision accumulator, distributed.
Cross-replica gradient reduction in f32 loses low bits as the replica count
grows (and is order-dependent).  We split each operand into error-free
mantissa slices (efts.mask_split, 12 bits each): the top-slice psum is
EXACT for up to 2^(24-2*12)=... practically the top slice sums exactly for
thousands of replicas (12-bit values, f32 accumulator), and each further
slice extends precision by 12 bits.  Recombination uses two_sum.  With
slices=2 this is df32-grade ("binary64-ish") reduction; slices=4 exceeds
f64.  This is the distributed cousin of the paper's binary128 MAC.

int8 all-reduce with error feedback — bandwidth-oriented gradient
compression: per-block int8 quantization before the reduce; the
quantization residual is fed back into the next step's gradient so the
error stays bounded instead of accumulating (Seide et al. / EF-SGD).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.efts import mask_split, quick_two_sum, two_sum

__all__ = ["compensated_psum", "int8_psum_ef", "EFState"]


def compensated_psum(x, axis_name: str, slices: int = 2):
    """High-precision psum over a mesh axis via error-free slice reduction."""
    residual = x
    parts = []
    for _ in range(max(1, slices - 1)):
        hi, residual = mask_split(residual)
        parts.append(jax.lax.psum(hi, axis_name))
    parts.append(jax.lax.psum(residual, axis_name))
    # recombine with exact two_sum chain (descending magnitude)
    s = parts[0]
    err = jnp.zeros_like(s)
    for p in parts[1:]:
        s, e = two_sum(s, p)
        err = err + e
    out, _ = quick_two_sum(s, err)
    return out


class EFState(NamedTuple):
    residual: jnp.ndarray  # carried quantization error (error feedback)


def _q8(x, block=256):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    b = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def int8_psum_ef(g, ef: EFState, axis_name: str):
    """int8-compressed psum with error feedback.

    Returns (reduced_fp32, new_ef).  The int8 payload is what would cross
    the wire (8x compression vs f32); the psum itself runs on the
    dequantized tensor because XLA collectives do not expose int8 ring
    stages — the quantization error behaviour (the part that affects
    convergence) is faithfully modeled, the bandwidth saving is structural.
    """
    comp = g + ef.residual
    q, scale = _q8(comp)
    deq = _dq8(q, scale, g.shape)
    new_ef = EFState(comp - deq)
    return jax.lax.psum(deq, axis_name), new_ef
