"""Failure handling for long-running training: restarts, watchdog, elasticity.

``run_with_restarts`` is the outer loop a 1000-node deployment runs under a
cluster scheduler: any step exception triggers restore-from-latest +
continue, up to a failure budget.  Combined with the stateless data
pipeline (batch = f(step)) and atomic checkpoints, a crash replays at most
``checkpoint_every`` steps and never corrupts state.

``StepWatchdog`` tracks a step-time EMA and flags stragglers (steps slower
than ``threshold``x the EMA) — on real fleets the flag feeds the
re-scheduling / hot-spare logic; here it feeds a callback (tested with
injected delays).
"""

from __future__ import annotations

import random
import time
from typing import Callable, Optional

__all__ = ["run_with_restarts", "restart_backoff", "StepWatchdog",
           "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Raised by tests to model preemption / node loss."""


class StepWatchdog:
    def __init__(self, threshold: float = 3.0, ema: float = 0.9,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ema_coef = ema
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.stragglers: list = []

    def observe(self, step: int, duration: float):
        if self.ema is None:
            self.ema = duration
            return False
        is_straggler = duration > self.threshold * self.ema
        if is_straggler:
            self.stragglers.append((step, duration, self.ema))
            if self.on_straggler:
                self.on_straggler(step, duration, self.ema)
            # do not fold outliers into the EMA
            return True
        self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * duration
        return False


def restart_backoff(failures: int, *, base: float = 0.0, cap: float = 30.0,
                    jitter: float = 0.1, seed: int = 0) -> float:
    """Wait before restart attempt number ``failures`` (1-based).

    Exponential with a cap — ``min(cap, base * 2^(failures-1))`` — times a
    seeded jitter factor in ``[1, 1 + jitter]``.  The exponential spreads
    a crash-looping job's retries out instead of hammering the shared
    filesystem/scheduler; the jitter de-synchronizes a fleet whose members
    all died at once (the thundering-herd restart).  Seeded (per-run, via
    ``seed``) rather than wall-clock random so a replayed run waits the
    same schedule — determinism is what lets the chaos suite assert the
    exact waits.  ``base=0`` (the default) keeps the historical
    restart-immediately behavior.
    """
    if base <= 0.0 or failures <= 0:
        return 0.0
    wait = min(cap, base * 2.0 ** (failures - 1))
    # one draw per attempt, independent of call history: attempt k of run
    # `seed` always jitters identically
    u = random.Random((seed << 20) ^ failures).random()
    return wait * (1.0 + jitter * u)


def run_with_restarts(make_state, train_step, ckpt_mgr, *, total_steps: int,
                      checkpoint_every: int = 10, max_failures: int = 5,
                      watchdog: Optional[StepWatchdog] = None,
                      on_restart: Optional[Callable[..., None]] = None,
                      backoff_base: float = 0.0, backoff_max: float = 30.0,
                      backoff_jitter: float = 0.1, seed: int = 0,
                      sleep: Callable[[float], None] = time.sleep):
    """Fault-tolerant train loop.

    make_state(restore_step | None) -> (state, start_step): builds fresh or
    restored state.  train_step(state, step) -> state.  Any exception rolls
    back to the latest checkpoint; the stateless data pipeline guarantees
    identical batches on replay.

    Restarts back off exponentially when ``backoff_base > 0``: attempt k
    waits ``min(backoff_max, backoff_base * 2^(k-1))`` scaled by a seeded
    jitter in ``[1, 1 + backoff_jitter]`` (see :func:`restart_backoff`).
    ``on_restart(step, failures, wait)`` receives the wait actually slept;
    two-argument legacy callbacks keep working.  ``sleep`` is injectable
    so tests assert the schedule without wall-clock cost.
    """
    failures = 0
    state, step = make_state(ckpt_mgr.latest_step())
    while step < total_steps:
        try:
            t0 = time.monotonic()
            state = train_step(state, step)
            if watchdog is not None:
                watchdog.observe(step, time.monotonic() - t0)
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                ckpt_mgr.save(state, step)
        except (SimulatedFailure, RuntimeError, OSError) as e:
            failures += 1
            if failures > max_failures:
                raise RuntimeError(
                    f"failure budget exhausted ({max_failures})") from e
            ckpt_mgr.wait()
            restore_step = ckpt_mgr.latest_step()
            wait = restart_backoff(failures, base=backoff_base,
                                   cap=backoff_max, jitter=backoff_jitter,
                                   seed=seed)
            if on_restart:
                try:
                    on_restart(step, failures, wait)
                except TypeError:
                    on_restart(step, failures)  # pre-backoff signature
            if wait > 0.0:
                sleep(wait)
            state, step = make_state(restore_step)
    ckpt_mgr.wait()
    return state, step, failures
