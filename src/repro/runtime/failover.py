"""Failure handling for long-running training: restarts, watchdog, elasticity.

``run_with_restarts`` is the outer loop a 1000-node deployment runs under a
cluster scheduler: any step exception triggers restore-from-latest +
continue, up to a failure budget.  Combined with the stateless data
pipeline (batch = f(step)) and atomic checkpoints, a crash replays at most
``checkpoint_every`` steps and never corrupts state.

``StepWatchdog`` tracks a step-time EMA and flags stragglers (steps slower
than ``threshold``x the EMA) — on real fleets the flag feeds the
re-scheduling / hot-spare logic; here it feeds a callback (tested with
injected delays).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

__all__ = ["run_with_restarts", "StepWatchdog", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Raised by tests to model preemption / node loss."""


class StepWatchdog:
    def __init__(self, threshold: float = 3.0, ema: float = 0.9,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ema_coef = ema
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.stragglers: list = []

    def observe(self, step: int, duration: float):
        if self.ema is None:
            self.ema = duration
            return False
        is_straggler = duration > self.threshold * self.ema
        if is_straggler:
            self.stragglers.append((step, duration, self.ema))
            if self.on_straggler:
                self.on_straggler(step, duration, self.ema)
            # do not fold outliers into the EMA
            return True
        self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * duration
        return False


def run_with_restarts(make_state, train_step, ckpt_mgr, *, total_steps: int,
                      checkpoint_every: int = 10, max_failures: int = 5,
                      watchdog: Optional[StepWatchdog] = None,
                      on_restart: Optional[Callable[[int, int], None]] = None):
    """Fault-tolerant train loop.

    make_state(restore_step | None) -> (state, start_step): builds fresh or
    restored state.  train_step(state, step) -> state.  Any exception rolls
    back to the latest checkpoint; the stateless data pipeline guarantees
    identical batches on replay.
    """
    failures = 0
    state, step = make_state(ckpt_mgr.latest_step())
    while step < total_steps:
        try:
            t0 = time.monotonic()
            state = train_step(state, step)
            if watchdog is not None:
                watchdog.observe(step, time.monotonic() - t0)
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                ckpt_mgr.save(state, step)
        except (SimulatedFailure, RuntimeError, OSError) as e:
            failures += 1
            if failures > max_failures:
                raise RuntimeError(
                    f"failure budget exhausted ({max_failures})") from e
            ckpt_mgr.wait()
            restore_step = ckpt_mgr.latest_step()
            if on_restart:
                on_restart(step, failures)
            state, step = make_state(restore_step)
    ckpt_mgr.wait()
    return state, step, failures
