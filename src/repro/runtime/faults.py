"""Hazard taxonomy + deterministic fault injection (the chaos harness).

The paper's FPGA pipeline earns its keep because binary128 results can be
*trusted* on ill-conditioned workloads — so the software engine needs an
explicit failure model, not silent NaN propagation.  This module owns both
halves of that model (DESIGN.md §12):

  * the **hazard taxonomy** — the typed errors every guarded layer raises.
    :class:`NumericalHazardError` (NaN/Inf/overflow, naming the offending
    operand), its subclass :class:`SliceOverflowError` (Ozaki
    slice-extraction anchor overflow, which otherwise corrupts slices
    silently), and :class:`BackendExecutionError` (a kernel backend failed
    and so did every declared fallback).  ``repro.gemm.guard`` raises the
    first two; the engine's failover loop raises the third.

  * the **fault-injection harness** — :class:`FaultPlan`, a frozen record
    of seeded :class:`Injection` specs, armed process-wide via the
    :func:`inject` context manager.  Production code carries cheap hooks
    (``poke``/``corrupt``/``zero_panel``) that are inert (one ``is None``
    test) unless a plan is armed, so the hot path pays nothing.  Injection
    classes cover the chaos suite's fault matrix: limb flips and NaN/Inf
    tile poison (``corrupt``), synthetic backend failures (``poke`` on
    ``backend.<name>`` sites), SUMMA panel loss (``zero_panel``, baked
    into the traced K-loop at a chosen step), autotune-cache corruption
    (``chaos_cache``), and mid-refinement kills (``poke`` on
    ``refine.kill``).  Every firing is logged, so tests can assert a fault
    actually happened before asserting it was detected or recovered.

Injections are deterministic: entry selection derives from
``FaultPlan.seed`` and the site name (via crc32, not Python's salted
``hash``), and each injection disarms after ``times`` firings — the same
plan replays the same faults, which is what lets ``run_with_restarts``
recovery be asserted exactly.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "NumericalHazardError", "SliceOverflowError", "BackendExecutionError",
    "InjectedFault", "BackendFailoverWarning",
    "Injection", "FaultPlan", "inject", "active", "fired", "report",
    "poke", "corrupt", "zero_panel", "chaos_cache",
]


# --------------------------------------------------------------------------
# hazard taxonomy
# --------------------------------------------------------------------------


class NumericalHazardError(ArithmeticError):
    """A guarded execution found NaN/Inf/overflow or a shadow mismatch.

    Carries *where* the hazard sits so callers can act on it: ``operand``
    ("A" | "B" | "C" | "output"), ``kind`` ("nan" | "inf" | "overflow" |
    "mismatch"), the first offending ``index``, and the plan's
    ``backend``/``precision``.  ``report`` is the JSON-able summary the
    chaos artifact collects.
    """

    def __init__(self, message: str, *, kind: str = "nan",
                 operand: str = "output", backend: str = "?",
                 precision: str = "?", index: Optional[tuple] = None,
                 nan_count: int = 0, inf_count: int = 0,
                 detail: Optional[str] = None):
        super().__init__(message)
        self.kind = kind
        self.operand = operand
        self.backend = backend
        self.precision = precision
        self.index = index
        self.nan_count = int(nan_count)
        self.inf_count = int(inf_count)
        self.detail = detail

    @property
    def report(self) -> Dict[str, Any]:
        return {
            "error": type(self).__name__, "kind": self.kind,
            "operand": self.operand, "backend": self.backend,
            "precision": self.precision, "index": self.index,
            "nan_count": self.nan_count, "inf_count": self.inf_count,
            "detail": self.detail,
        }


class SliceOverflowError(NumericalHazardError):
    """Operand magnitude exceeds the Ozaki slice-extraction anchor range.

    Rump's ExtractVector builds its fixed-point anchor as
    ``sigma = 2^(e + p - beta)`` from the row/col magnitude ``2^e``; for
    ``e`` within ``p - beta`` octaves of the limb dtype's max exponent the
    anchor overflows to Inf and ``(x + sigma) - sigma`` turns every slice
    into NaN — *after* extraction, so without this check the corruption
    surfaces only as an unexplained NaN product (or, one octave lower, as
    silently saturated slices).  Raised by ``check="finite"``/``"full"``
    before the sliced backends run.
    """


class BackendExecutionError(RuntimeError):
    """A kernel backend failed and every declared fallback failed too.

    ``attempts`` is the ordered tuple of ``(backend, repr(error))`` pairs
    actually tried — the receipt of the failover walk.
    """

    def __init__(self, message: str,
                 attempts: Tuple[Tuple[str, str], ...] = ()):
        super().__init__(message)
        self.attempts = tuple(attempts)


class InjectedFault(RuntimeError):
    """The synthetic failure an armed ``Injection(kind="raise")`` raises.

    A ``RuntimeError`` on purpose: the recovery machinery under test
    (``run_with_restarts``, the engine failover loop) must catch it through
    the same ``except`` clauses that catch the real fault it models.
    """

    def __init__(self, site: str, message: Optional[str] = None):
        super().__init__(message or f"injected fault at {site!r}")
        self.site = site


class BackendFailoverWarning(RuntimeWarning):
    """A backend failed (or is quarantined) and a fallback took over."""


# --------------------------------------------------------------------------
# fault plans
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Injection:
    """One seeded fault.  ``site`` names the hook that fires it:

    ==================  =========================  =======================
    site                kinds                      meaning
    ==================  =========================  =======================
    ``gemm.a|b|c|out``  nan, inf, limb_flip, neg   poison/flip entries of
                                                   an engine operand or of
                                                   the computed product
    ``backend.<name>``  raise                      that backend's kernel
                                                   "fails to lower"
    ``summa.panel.a|b`` zero                       the K-step ``step``'s
                                                   broadcast panel is lost
    ``refine.kill``     raise                      refinement iteration
                                                   ``step`` dies mid-flight
    ``cache.file``      truncate, garbage, delete  autotune-cache file
                                                   corruption (via
                                                   ``chaos_cache``)
    ==================  =========================  =======================

    ``times`` firings arm the injection (then it disarms); ``step``
    selects a SUMMA K-step / refinement iteration where that applies;
    ``frac`` is the poisoned-entry fraction for nan/inf kinds; ``limb``
    picks the limb plane; ``scale`` is the limb_flip multiplier (2.0 = an
    exponent-bit upset, the classic single-event model).
    """

    site: str
    kind: str = "raise"
    times: int = 1
    step: Optional[int] = None
    frac: float = 0.05
    limb: int = 0
    scale: float = 2.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic chaos schedule: seed + injection specs."""

    seed: int = 0
    injections: Tuple[Injection, ...] = ()


class _Armed:
    """Mutable runtime state of one armed FaultPlan."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.remaining = [inj.times for inj in plan.injections]
        self.log: List[Dict[str, Any]] = []


_ACTIVE: Optional[_Armed] = None


def active() -> bool:
    """True iff a FaultPlan is armed (the hooks' one-branch fast path)."""
    return _ACTIVE is not None


def fired() -> List[Dict[str, Any]]:
    """Log of injections that actually fired under the current plan."""
    return list(_ACTIVE.log) if _ACTIVE is not None else []


def report() -> Dict[str, Any]:
    """JSON-able summary of the armed plan (the chaos-artifact payload)."""
    if _ACTIVE is None:
        return {"active": False, "fired": []}
    return {
        "active": True,
        "seed": _ACTIVE.plan.seed,
        "injections": [dataclasses.asdict(i) for i in _ACTIVE.plan.injections],
        "fired": fired(),
    }


def _clear_trace_caches() -> None:
    # injections that run at *trace* time (zero_panel inside the SUMMA
    # fori_loop body) bake the fault into compiled graphs; dropping the
    # engine's compile caches on arm AND disarm guarantees no faulty trace
    # outlives its FaultPlan and no clean trace masks an armed one
    try:
        from repro.gemm import engine
    except Exception:  # gemm not importable (partial install): nothing cached
        return
    for fn in (engine._execute_2d_jit, engine._execute_batched_jit,
               engine._execute_fused_alpha_jit, engine._execute_fused_full_jit,
               engine._apply_epilogue_jit):
        fn.clear_cache()
    engine._summa_runner_jit.cache_clear()


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Arm a FaultPlan for the dynamic extent of the ``with`` block.

    Not reentrant (a chaos experiment is one schedule); yields the armed
    state so tests can inspect ``fired()`` mid-flight.
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a FaultPlan is already armed (inject() does "
                           "not nest — one chaos schedule at a time)")
    _clear_trace_caches()
    _ACTIVE = _Armed(plan)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = None
        _clear_trace_caches()


def _fire(site: str, **ctx) -> Optional[Injection]:
    """Consume one firing of the first armed injection matching ``site``.

    ``iteration=`` in ``ctx`` must equal the injection's ``step`` when one
    is pinned (the refinement-kill selector); SUMMA ``step`` matching is
    instead baked into the traced graph by ``zero_panel``.
    """
    if _ACTIVE is None:
        return None
    for i, inj in enumerate(_ACTIVE.plan.injections):
        if inj.site != site or _ACTIVE.remaining[i] <= 0:
            continue
        if inj.step is not None and "iteration" in ctx \
                and ctx["iteration"] != inj.step:
            continue
        _ACTIVE.remaining[i] -= 1
        _ACTIVE.log.append({"site": site, "kind": inj.kind,
                            "remaining": _ACTIVE.remaining[i], **ctx})
        return inj
    return None


def _site_rng(site: str) -> np.random.Generator:
    seed = _ACTIVE.plan.seed if _ACTIVE is not None else 0
    return np.random.default_rng(
        (seed << 32) ^ zlib.crc32(site.encode("utf-8")))


# --------------------------------------------------------------------------
# hooks (called by production code; inert without an armed plan)
# --------------------------------------------------------------------------


def poke(site: str, **ctx) -> None:
    """Raise :class:`InjectedFault` if a ``raise``-kind injection is armed.

    The hook for control-flow faults: a backend that "fails to lower"
    (``backend.<name>`` sites, fired at trace time inside the engine
    dispatch) or a refinement iteration killed mid-flight
    (``refine.kill``, matched on ``iteration=``).
    """
    inj = _fire(site, **ctx)
    if inj is not None and inj.kind == "raise":
        raise InjectedFault(site)


def corrupt(site: str, x):
    """Return ``x`` with an armed data fault applied (else ``x`` itself).

    ``x`` is a multi-limb value.  ``nan``/``inf`` poison ``frac`` of the
    entries of limb ``limb``; ``limb_flip`` multiplies one seeded entry by
    ``scale`` (default 2 — an exponent-bit upset: *finite but wrong*, the
    case only the ``check="full"`` shadow product can see); ``neg`` flips
    one entry's sign.  Selection is seeded and shape-static, so the same
    mask applies whether ``x`` is concrete or traced.
    """
    inj = _fire(site)
    if inj is None:
        return x
    import jax.numpy as jnp

    from repro.core import mp

    ls = list(mp.limbs(x))
    li = min(inj.limb, len(ls) - 1)
    l = ls[li]
    size = int(np.prod(l.shape)) or 1
    rng = _site_rng(site)
    if inj.kind in ("nan", "inf"):
        n_bad = max(1, int(inj.frac * size))
        flat = rng.choice(size, size=n_bad, replace=False)
        mask = np.zeros(l.shape, bool)
        mask.reshape(-1)[flat] = True
        payload = np.nan if inj.kind == "nan" else np.inf
        ls[li] = jnp.where(jnp.asarray(mask), payload, l)
    elif inj.kind in ("limb_flip", "neg"):
        mask = np.zeros(l.shape, bool)
        mask.reshape(-1)[int(rng.integers(size))] = True
        factor = inj.scale if inj.kind == "limb_flip" else -1.0
        ls[li] = jnp.where(jnp.asarray(mask), l * factor, l)
    else:
        raise ValueError(f"unknown corrupt kind {inj.kind!r} at {site!r}")
    _ACTIVE.log[-1]["shape"] = tuple(l.shape)
    return mp.from_limbs(ls)


def zero_panel(site: str, panel, t):
    """Zero a SUMMA broadcast panel at K-step ``step`` (traced selector).

    Called inside the engine's ``fori_loop`` body at trace time; the
    firing bakes a ``where(t == step, 0, panel)`` into the graph — the
    deterministic model of "the owning shard's panel contribution was
    lost at step ``step``".  ``inject`` clears the engine's compile caches
    on arm/disarm so the faulty trace cannot leak out of the plan's scope.
    """
    inj = _fire(site)
    if inj is None or inj.kind != "zero":
        return panel
    import jax.numpy as jnp

    from repro.core import mp

    step = inj.step or 0
    _ACTIVE.log[-1]["step"] = step
    return mp.map_limbs(
        lambda l: jnp.where(jnp.asarray(t) == step, jnp.zeros_like(l), l),
        panel)


def chaos_cache(path: str) -> List[str]:
    """Apply armed ``cache.file`` injections to an autotune-cache file.

    ``truncate`` cuts the file mid-JSON (the killed-writer artifact the
    atomic write protocol is meant to make impossible — injecting it
    proves the *reader* still degrades to heuristics); ``garbage``
    replaces the content with non-JSON; ``delete`` unlinks it.  Returns
    the kinds applied.
    """
    applied = []
    while True:
        inj = _fire("cache.file")
        if inj is None:
            break
        if inj.kind == "truncate":
            with open(path, "rb") as f:
                raw = f.read()
            with open(path, "wb") as f:
                f.write(raw[: max(1, len(raw) // 2)])
        elif inj.kind == "garbage":
            with open(path, "w") as f:
                f.write('{"v?/corrupted": [not json')
        elif inj.kind == "delete":
            try:
                os.unlink(path)
            except OSError:
                pass
        else:
            raise ValueError(f"unknown cache.file kind {inj.kind!r}")
        applied.append(inj.kind)
    return applied
