"""Logical-axis sharding rules (t5x-style) for the production mesh.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", ...); a ``ShardingRules`` table maps those to mesh axes per
deployment.  This keeps DP/FSDP/TP/EP/SP decisions in one place and makes
elastic re-meshing a rule-table swap, not a model change.

Two rule tables exist because parameters and activations shard differently:
parameters are ZeRO-3/FSDP-sharded over the data(+pod) axes on their
non-tensor-parallel dimension, while activations shard batch over
data(+pod) and the TP dimension over model.

Use ``activate(mesh, rules)`` (context manager) in drivers; model code calls
``constrain(x, *names)`` which is a no-op when no context is active (unit
tests, single CPU device).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, tuple]

__all__ = [
    "ShardingRules",
    "activate",
    "current",
    "constrain",
    "logical_spec",
    "param_sharding",
    "act_sharding",
    "DEFAULT_PARAM_RULES",
    "DEFAULT_ACT_RULES",
]

# parameters: FSDP over data(+pod) on the "embed"-like dimension, TP over
# model on heads/ffn/vocab/experts
DEFAULT_PARAM_RULES: dict = {
    "embed": "data",          # ZeRO-3 shard dim (joined by "pod" when present)
    "embed_pod": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",       # EP: experts live on the model axis
    "expert_ffn": None,
    "layers": None,
    "conv": None,
    "state": None,
    "norm": None,
}

# activations: batch over (pod, data), TP dims over model, seq optionally
# over data (sequence parallelism for long-context serving)
DEFAULT_ACT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "data",         # sequence-parallel alternative
    # Megatron-SP: the residual stream between blocks shards its seq dim
    # over the TP axis — the remat-saved per-layer activations otherwise
    # dominate device memory (17 GB/dev at 405B; see EXPERIMENTS.md)
    "seq_res": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    param_rules: Mapping[str, AxisVal] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_PARAM_RULES))
    act_rules: Mapping[str, AxisVal] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_ACT_RULES))

    def _resolve(self, rules: Mapping[str, AxisVal], names: Sequence[Optional[str]]) -> P:
        axes = []
        used = set()
        for name in names:
            if name is None:
                axes.append(None)
                continue
            val = rules.get(name, None)
            # drop mesh axes not present in this mesh (elastic downsizing)
            # and axes already consumed by an earlier dimension (a mesh axis
            # may appear only once in a PartitionSpec)
            if isinstance(val, tuple):
                val = tuple(v for v in val if v in self.mesh.axis_names and v not in used)
                val = val if val else None
            elif val is not None and (val not in self.mesh.axis_names or val in used):
                val = None
            if val is None:
                axes.append(None)
                continue
            for v in (val if isinstance(val, tuple) else (val,)):
                used.add(v)
            axes.append(val)
        return P(*axes)

    def param_spec(self, *names) -> P:
        return self._resolve(self.param_rules, names)

    def act_spec(self, *names) -> P:
        return self._resolve(self.act_rules, names)


_local = threading.local()


def current() -> Optional[ShardingRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def activate(rules: ShardingRules):
    prev = current()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def constrain(x, *names):
    """with_sharding_constraint by logical activation axis names (no-op

    outside an activated sharding context, so unit tests run unsharded)."""
    rules = current()
    if rules is None:
        return x
    spec = rules.act_spec(*names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def logical_spec(names: Sequence[Optional[str]], kind: str = "param") -> P:
    rules = current()
    if rules is None:
        return P()
    return rules.param_spec(*names) if kind == "param" else rules.act_spec(*names)


def param_sharding(rules: ShardingRules, logical_axes) -> NamedSharding:
    return NamedSharding(rules.mesh, rules.param_spec(*logical_axes))


def act_sharding(rules: ShardingRules, logical_axes) -> NamedSharding:
    return NamedSharding(rules.mesh, rules.act_spec(*logical_axes))
