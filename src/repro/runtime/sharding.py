"""Logical-axis sharding rules (t5x-style) for the production mesh.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", ...); a ``ShardingRules`` table maps those to mesh axes per
deployment.  This keeps DP/FSDP/TP/EP/SP decisions in one place and makes
elastic re-meshing a rule-table swap, not a model change.

Two rule tables exist because parameters and activations shard differently:
parameters are ZeRO-3/FSDP-sharded over the data(+pod) axes on their
non-tensor-parallel dimension, while activations shard batch over
data(+pod) and the TP dimension over model.

Use ``activate(mesh, rules)`` (context manager) in drivers; model code calls
``constrain(x, *names)`` which is a no-op when no context is active (unit
tests, single CPU device).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, tuple]

__all__ = [
    "ShardingRules",
    "activate",
    "current",
    "constrain",
    "logical_spec",
    "param_sharding",
    "act_sharding",
    "gemm_mesh_axes",
    "DEFAULT_PARAM_RULES",
    "DEFAULT_ACT_RULES",
    "DEFAULT_GEMM_RULES",
]

# parameters: FSDP over data(+pod) on the "embed"-like dimension, TP over
# model on heads/ffn/vocab/experts
DEFAULT_PARAM_RULES: dict = {
    "embed": "data",          # ZeRO-3 shard dim (joined by "pod" when present)
    "embed_pod": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",       # EP: experts live on the model axis
    "expert_ffn": None,
    "layers": None,
    "conv": None,
    "state": None,
    "norm": None,
}

# activations: batch over (pod, data), TP dims over model, seq optionally
# over data (sequence parallelism for long-context serving)
DEFAULT_ACT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "data",         # sequence-parallel alternative
    # Megatron-SP: the residual stream between blocks shards its seq dim
    # over the TP axis — the remat-saved per-layer activations otherwise
    # dominate device memory (17 GB/dev at 405B; see EXPERIMENTS.md)
    "seq_res": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
}


# GEMM output logical axes for the engine's 2-D SUMMA distribution
# (repro.gemm): "gemm_m" is the C row dimension, "gemm_n" the C column
# dimension.  Each value lists mesh-axis *candidates* in preference order —
# the first name present in the mesh (and not already claimed) wins, so the
# GEMM layer composes with both dedicated GEMM meshes (("rows", "cols"))
# and the production LM meshes above (("data", "model")) without anyone
# hand-threading axis names.
DEFAULT_GEMM_RULES: dict = {
    "gemm_m": ("rows", "m", "x", "data", "pod"),
    "gemm_n": ("cols", "n", "y", "model"),
}


def gemm_mesh_axes(mesh: Mesh,
                   m_axis: Optional[str] = None,
                   n_axis: Optional[str] = None,
                   rules: Optional[Mapping[str, Sequence[str]]] = None,
                   ) -> tuple:
    """Name the (M, N) mesh axes of a 2-D GEMM distribution.

    Resolution mirrors ``ShardingRules``: logical axes ("gemm_m",
    "gemm_n") map to mesh axes through a rule table, axes absent from the
    mesh are dropped, and a mesh axis is consumed at most once.  Explicit
    ``m_axis``/``n_axis`` arguments win outright; otherwise the first
    rule candidate present in the mesh is chosen, falling back to mesh
    declaration order.  A 1-axis mesh yields ``(axis, None)`` — the
    degenerate pure-row-sharded topology.
    """
    tbl = dict(DEFAULT_GEMM_RULES)
    if rules:
        tbl.update(rules)
    names = list(mesh.axis_names)
    for ax, which in ((m_axis, "m_axis"), (n_axis, "n_axis")):
        if ax is not None and ax not in names:
            raise ValueError(f"{which}={ax!r} is not a mesh axis of "
                             f"{tuple(names)}")

    def pick(logical: str, taken: set) -> Optional[str]:
        for cand in tbl.get(logical, ()):
            if cand in names and cand not in taken:
                return cand
        for cand in names:  # fall back to mesh declaration order
            if cand not in taken:
                return cand
        return None

    m_ax = m_axis or pick("gemm_m", {n_axis} if n_axis else set())
    if n_axis is not None:
        n_ax = n_axis
    else:
        n_ax = pick("gemm_n", {m_ax}) if len(names) > 1 else None
    if m_ax is not None and m_ax == n_ax:
        raise ValueError(f"M and N cannot share mesh axis {m_ax!r}")
    return m_ax, n_ax


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    param_rules: Mapping[str, AxisVal] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_PARAM_RULES))
    act_rules: Mapping[str, AxisVal] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_ACT_RULES))

    def _resolve(self, rules: Mapping[str, AxisVal], names: Sequence[Optional[str]]) -> P:
        axes = []
        used = set()
        for name in names:
            if name is None:
                axes.append(None)
                continue
            val = rules.get(name, None)
            # drop mesh axes not present in this mesh (elastic downsizing)
            # and axes already consumed by an earlier dimension (a mesh axis
            # may appear only once in a PartitionSpec)
            if isinstance(val, tuple):
                val = tuple(v for v in val if v in self.mesh.axis_names and v not in used)
                val = val if val else None
            elif val is not None and (val not in self.mesh.axis_names or val in used):
                val = None
            if val is None:
                axes.append(None)
                continue
            for v in (val if isinstance(val, tuple) else (val,)):
                used.add(v)
            axes.append(val)
        return P(*axes)

    def param_spec(self, *names) -> P:
        return self._resolve(self.param_rules, names)

    def act_spec(self, *names) -> P:
        return self._resolve(self.act_rules, names)


_local = threading.local()


def current() -> Optional[ShardingRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def activate(rules: ShardingRules):
    prev = current()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def constrain(x, *names):
    """with_sharding_constraint by logical activation axis names (no-op

    outside an activated sharding context, so unit tests run unsharded)."""
    rules = current()
    if rules is None:
        return x
    spec = rules.act_spec(*names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def logical_spec(names: Sequence[Optional[str]], kind: str = "param") -> P:
    rules = current()
    if rules is None:
        return P()
    return rules.param_spec(*names) if kind == "param" else rules.act_spec(*names)


def param_sharding(rules: ShardingRules, logical_axes) -> NamedSharding:
    return NamedSharding(rules.mesh, rules.param_spec(*logical_axes))


def act_sharding(rules: ShardingRules, logical_axes) -> NamedSharding:
    return NamedSharding(rules.mesh, rules.act_spec(*logical_axes))
