"""Tiered iterative-refinement solver subsystem (DESIGN.md §10).

``rgesv`` (general) / ``rposv`` (SPD) factor once at a cheap ladder rung,
refine GEMM-rich residuals at the target tier through the engine, and
escalate up the (data-driven, ``ladder=``-overridable) rung list —
default f64 -> dd -> td -> qd — when the residual stagnates.
``lu_solve_refined`` / ``cholesky_solve_refined`` bolt the same loop onto
an existing ``rgetrf`` / ``rpotrf`` factorization.
"""

from .refine import (
    LADDER_CELLS,
    TIERS,
    RefinementInfo,
    cholesky_solve_refined,
    lu_solve_refined,
    rgesv,
    rposv,
    tier_eps,
)

__all__ = [
    "TIERS", "LADDER_CELLS", "RefinementInfo", "rgesv", "rposv",
    "lu_solve_refined", "cholesky_solve_refined", "tier_eps",
]
