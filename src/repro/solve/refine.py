"""Tiered iterative-refinement linear solvers (the paper's application layer).

The paper's headline applications — LU decomposition and SDP — need
binary128 only to *stabilize* a solve, not to carry every flop.  That is
the classic mixed-precision iterative-refinement setting: factor A once at
a cheap tier, then recover target-tier accuracy from GEMM-rich residual
corrections,

    factor   P A = L U            at  u_factor   (f64, dd, td, or qd)
    repeat   r = b - A x          at  u_target   (one engine ``execute``)
             d = U \\ (L \\ P r)    at  u_factor
             x = x + d            at  u_target

which converges at rate ~ cond(A) * u_factor per step as long as
cond(A) < 1/u_factor.  When it does not — the residual stagnates — the
solver *escalates* the factorization tier up the ladder (by default
f64 -> dd -> td -> qd; ``ladder=`` overrides the rung sequence) and
keeps going, so one entry point serves the whole precision range and
only ill-conditioned solves pay for the expensive rungs (DESIGN.md §10
has the cost model).

The residual is a single engine call per iteration: ``execute(plan, A, x,
alpha=-1, beta=1, c=b)`` rides the fused alpha/beta epilogue, the batched
(vmap) multi-RHS path, and 2-D SUMMA mesh sharding (a ``mesh=`` override
distributes rows over ``shard_axis`` and RHS columns over
``shard_axis_n`` — batched + sharded composes in the same call) exactly
like every other GEMM in the repo; ``comm=``/``k_stream=`` overrides
select the SUMMA panel schedule (ppermute ring vs masked psum) and
host-side out-of-core K streaming, and tier escalation re-plans carry
both (``replan_precision``).  Everything per-iteration is jit-compiled once per
(plan, tier) — pivots are traced JAX arrays end-to-end, so the pivoted
correction solve lives inside the same jit as the update.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np

from repro.core import mp
from repro.core.blas import rlange
from repro.core.linalg import (
    cholesky_solve,
    lu_solve,
    rgetrf,
    rpotrf,
)
from repro.gemm import execute, make_plan, replan_precision
from repro.runtime import faults as _faults

__all__ = ["TIERS", "LADDER_CELLS", "RefinementInfo", "rgesv", "rposv",
           "lu_solve_refined", "cholesky_solve_refined", "tier_eps"]

# the default escalation ladder, cheapest first.  Solvers take a
# ``ladder=`` override (any strictly-ascending subset of the supported
# rungs), so a caller can e.g. skip td (the pre-td behavior,
# ("f64", "dd", "qd")) or pin the climb to ("dd", "td").
TIERS = ("f64", "dd", "td", "qd")

# every meaningful (factor_tier, target_tier) pair: factor at or below
# the target, target always an extended tier.  The single source for the
# conformance matrix, the solver test sweep, and the bench_lu cost rows —
# a new rung lands in all three automatically.
LADDER_CELLS = tuple(
    (f, t) for t in TIERS if t != "f64"
    for f in TIERS if TIERS.index(f) <= TIERS.index(t))

_TIER_ALIASES = {
    "f64": "f64", "double": "f64", "float64": "f64",
    "dd": "dd", "binary128": "dd", "dd64": "dd",
    "td": "td", "binary192": "td", "td64": "td",
    "qd": "qd", "binary128+": "qd", "qd64": "qd",
}

# trace log for the compile-once regression test: one entry is appended
# per *trace* of a refinement-step jit (tracing runs this Python body;
# cached executions do not), keyed by what the jit specializes on
_TRACE_EVENTS: List[tuple] = []


def _tier(name: str) -> str:
    try:
        return _TIER_ALIASES[name]
    except KeyError:
        raise ValueError(f"unknown tier {name!r}; one of {sorted(set(_TIER_ALIASES))}")


def _rank(tier: str) -> int:
    """Cost/precision rank of a rung: its limb count (f64 counts as one)."""
    return 1 if tier == "f64" else mp.PRECISIONS[tier]


def _resolve_ladder(ladder) -> tuple:
    """Canonicalize a ``ladder=`` override (None -> the default TIERS).

    Rungs must be known tiers in strictly-ascending precision order —
    escalation walks the tuple left to right and each climb must actually
    buy accuracy.
    """
    rungs = tuple(_tier(t) for t in (TIERS if ladder is None else ladder))
    if not rungs:
        raise ValueError("ladder must name at least one rung")
    ranks = [_rank(t) for t in rungs]
    if any(hi <= lo for lo, hi in zip(ranks, ranks[1:])):
        raise ValueError(f"ladder rungs must be strictly ascending, "
                         f"cheapest first; got {rungs}")
    return rungs


def tier_eps(tier: str) -> float:
    """Unit roundoff of a ladder rung (f64 included)."""
    t = _tier(tier)
    return 2.0 ** -53 if t == "f64" else mp.eps(t)


def _is_ml(x) -> bool:
    try:
        mp.precision_of(x)
        return True
    except TypeError:
        return False


def _as_tier(x, tier: str):
    """Coerce an f64 array / dd / td / qd value to a ladder rung.

    Climbing (f64 -> dd -> td -> qd) is exact (zero-limb padding); descending
    rounds to the cheaper tier — exactly what handing a residual to a
    cheap factorization wants.
    """
    t = _tier(tier)
    if _is_ml(x):
        return jnp.asarray(mp.to_float(x)) if t == "f64" else mp.promote(x, t)
    x = jnp.asarray(x, jnp.float64)
    return x if t == "f64" else mp.from_float(x, t)


# --------------------------------------------------------------------------
# factorizations (one per ladder rung, built lazily on escalation)
# --------------------------------------------------------------------------


@jax.jit
def _lu_factor_f64(a64):
    return jsl.lu_factor(a64)


@jax.jit
def _chol_factor_f64(a64):
    return jnp.linalg.cholesky(a64)


def _factorize(a_target, tier: str, assume: str, block: int):
    """Factor A (held at the target tier) at a ladder rung."""
    a_f = _as_tier(a_target, tier)
    if tier == "f64":
        return _chol_factor_f64(a_f) if assume == "pos" \
            else _lu_factor_f64(a_f)
    if assume == "pos":
        return rpotrf(a_f)
    return rgetrf(a_f, block=block)


def _fsolve(fac, tier: str, assume: str, rhs):
    """Solve with a rung's factorization; rhs and result live at that rung.

    rhs is (n, ncols) — batched systems are flattened to columns by the
    caller (triangular substitution is column-independent).
    """
    if tier == "f64":
        if assume == "pos":
            y = jsl.solve_triangular(fac, rhs, lower=True)
            return jsl.solve_triangular(fac.T, y, lower=False)
        return jsl.lu_solve(fac, rhs)
    if assume == "pos":
        return cholesky_solve(fac, rhs)
    lu, piv = fac
    return lu_solve(lu, piv, rhs)


# --------------------------------------------------------------------------
# jitted refinement steps (compiled once per plan / tier combination)
# --------------------------------------------------------------------------


def _cols(x, n: int):
    """(..., n, nrhs) -> (n, batch*nrhs) column view (and its inverse)."""
    return mp.map_limbs(
        lambda l: jnp.moveaxis(l, -2, 0).reshape(n, -1), x)


def _uncols(x2d, like):
    shp = mp.limbs(like)[0].shape
    return mp.map_limbs(
        lambda l: jnp.moveaxis(l.reshape(shp[-2:-1] + shp[:-2] + shp[-1:]),
                               0, -2), x2d)


@jax.jit
def _col_max(x):
    """Per-column max |entry| (shape (..., nrhs)) of a multi-limb value.

    The leading limb decides magnitude ordering of a normalized
    expansion, so the f64 column maxes are exact to f64 resolution.
    """
    return jnp.max(jnp.abs(mp.limbs(x)[0]), axis=-2)


@functools.partial(jax.jit, static_argnames=("plan",))
def _residual_step(a_t, b_t, x, *, plan):
    """r = b - A x at the target tier — one engine call, fused epilogue.

    Returns (r, per-column |r|_max, per-column |x|_max); the norms ride
    the same jit so the convergence metric costs no extra eager
    multi-limb passes.  Column-wise (LAPACK xGERFS-style) because a
    global max would let one large-scale RHS column mask another column
    still far from its own backward-error target.
    """
    _TRACE_EVENTS.append(("residual", plan.precision, plan.backend,
                          plan.batch_shape))
    r = execute(plan, a_t, x, alpha=-1.0, beta=1.0, c=b_t)
    return r, _col_max(r), _col_max(x)


@functools.partial(jax.jit,
                   static_argnames=("factor_tier", "target_tier", "assume"))
def _correct_step(fac, r, x, *, factor_tier, target_tier, assume):
    """x + A^-1 r through the rung factorization, update at target tier."""
    _TRACE_EVENTS.append(("correct", factor_tier, target_tier, assume))
    n = x.shape[-2]
    r_f = _as_tier(_cols(r, n), factor_tier)
    d_f = _fsolve(fac, factor_tier, assume, r_f)
    d = _uncols(_as_tier(d_f, target_tier), r)
    return mp.add(x, d)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RefinementInfo:
    """Convergence report of one refinement-backed solve."""

    converged: bool
    iterations: int
    target_tier: str
    tol: float
    backward_errors: List[float]          # berr of the iterate per iteration
    factor_tiers: List[str]               # rung in effect at each iteration
    escalations: List[dict]               # {iteration, from, to, ratio}
    factorizations: dict                  # rung -> count performed
    stagnations: int = 0
    # backward error of the RETURNED x.  Usually backward_errors[-1], but
    # when a diverged/NaN final step makes the solver fall back to the
    # best measured iterate, this is that iterate's berr — the history
    # stays an honest per-iteration log of what was measured
    final_backward_error: float = float("inf")
    # why a non-converged solve stopped refining: dicts with a "kind" of
    # "escalation-capped" (max_escalations= hit with rungs left unclimbed),
    # "ladder-exhausted" (stagnated at the top rung for this target), or
    # "iteration-budget" (max_iters ran out), plus the iteration/rung/
    # backward-error context.  Empty on a converged solve — so
    # ``converged or info.hazards`` always explains the outcome, and the
    # caller of a capped best-effort solve gets a report, not a bare throw
    hazards: List[dict] = dataclasses.field(default_factory=list)


def _refine(a, b, *, factor_tier, target_tier, assume, factorization,
            max_iters, tol, stagnation_ratio, block, plan, plan_overrides,
            max_escalations=None, ladder=None):
    if max_escalations is not None and max_escalations < 0:
        raise ValueError(f"max_escalations must be >= 0 or None, "
                         f"got {max_escalations}")
    ladder = _resolve_ladder(ladder)
    factor_tier = ladder[0] if factor_tier is None else _tier(factor_tier)
    if target_tier is None:
        target_tier = mp.precision_of(a) if _is_ml(a) else "dd"
    target_tier = _tier(target_tier)
    if target_tier == "f64":
        raise ValueError("target_tier must be an extended tier (dd, td, or "
                         "qd); a plain f64 solve needs no refinement "
                         "subsystem")
    if factor_tier not in ladder:
        raise ValueError(f"factor_tier {factor_tier!r} is not a rung of "
                         f"the ladder {ladder}")
    if target_tier not in ladder:
        raise ValueError(f"target_tier {target_tier!r} is not a rung of "
                         f"the ladder {ladder}")
    if ladder.index(factor_tier) > ladder.index(target_tier):
        raise ValueError(f"factor_tier {factor_tier!r} is above "
                         f"target_tier {target_tier!r} on the ladder")

    a_t = _as_tier(a, target_tier)
    vector_rhs = (jnp.ndim(b) if not _is_ml(b) else len(b.shape)) == 1
    b_t = _as_tier(b, target_tier)
    if vector_rhs:
        b_t = mp.map_limbs(lambda l: l[:, None], b_t)
    n = a_t.shape[-1]
    nrhs = b_t.shape[-1]
    batch_shape = tuple(b_t.shape[:-2])

    if plan is not None and plan_overrides:
        raise ValueError("pass either plan= or planner overrides, not both")
    if plan is None:
        plan = make_plan(n, n, nrhs, dtype=mp.limbs(a_t)[0].dtype,
                         precision=target_tier, batch_shape=batch_shape,
                         **plan_overrides)
    elif plan.precision != target_tier:
        plan = replan_precision(plan, n, n, nrhs, target_tier)

    if tol is None:
        tol = 2.0 * n * tier_eps(target_tier)

    anorm = float(rlange("i", a_t))
    bmax = np.asarray(_col_max(b_t), np.float64)  # per (batch, column)

    facs: dict = {}
    fac_counts = {t: 0 for t in ladder}
    if factorization is not None:
        facs[factor_tier] = factorization
    eager = plan.mesh is not None  # shard_map path: engine jits internally

    def get_fac(tier):
        if tier not in facs:
            facs[tier] = _factorize(a_t, tier, assume, block)
            fac_counts[tier] += 1
        return facs[tier]

    x = mp.zeros(b_t.shape, target_tier, dtype=mp.limbs(b_t)[0].dtype)
    history: List[float] = []
    tiers_hist: List[str] = []
    escalations: List[dict] = []
    stagnations = 0
    converged = False
    prev_berr = None
    best: Optional[Tuple[float, Any]] = None
    it = 0
    x_measured = True  # x=0 is trivially known; corrections unmeasure x

    def measure(x):
        if eager:
            r = execute(plan, a_t, x, alpha=-1.0, beta=1.0, c=b_t)
            rmax, xmax = _col_max(r), _col_max(x)
        else:
            r, rmax, xmax = _residual_step(a_t, b_t, x, plan=plan)
        # the LAPACK per-column backward error, worst column governs:
        # stopping on a global max would declare a small-scale column
        # converged on the strength of a large-scale one
        rmax = np.asarray(rmax, np.float64)
        denom = anorm * np.asarray(xmax, np.float64) + bmax
        with np.errstate(divide="ignore", invalid="ignore"):
            cells = np.where(denom > 0, rmax / denom,
                             np.where(rmax == 0, 0.0, np.inf))
        return r, float(np.max(cells))

    hazards: List[dict] = []

    def hazard(kind, berr):
        hazards.append({
            "kind": kind, "iteration": it, "rung": factor_tier,
            "target": target_tier, "backward_error": berr,
            "finite": math.isfinite(berr),
        })

    while it < max_iters:
        it += 1
        # chaos hook: an armed "refine.kill" injection (step=iteration)
        # raises here, modelling a preempted/died refinement iteration —
        # the runtime.failover restart harness is what must absorb it
        _faults.poke("refine.kill", iteration=it)
        r, berr = measure(x)
        x_measured = True
        history.append(berr)
        tiers_hist.append(factor_tier)
        finite = math.isfinite(berr)
        if finite and (best is None or berr < best[0]):
            best = (berr, x)
        if finite and berr <= tol:
            converged = True
            break
        if (not finite) or (prev_berr is not None
                            and berr > stagnation_ratio * prev_berr):
            # stagnation: this rung's factorization can no longer cut the
            # backward error (cond(A) * u_factor ~ 1).  A non-finite berr
            # is the hard form of the same failure — the rung's
            # factorization broke down outright (e.g. a dd Cholesky of a
            # cond >> 1/u_dd Schur complement goes indefinite under
            # rounding and NaNs).
            stagnations += 1
            nxt = ladder.index(factor_tier) + 1
            # bounded escalation: a cap turns "climb until the ladder ends"
            # into "climb at most N rungs, then return best-effort with a
            # hazard report" — the serving posture, where a runaway qd
            # refactorization is worse than a documented dd-grade answer
            capped = (max_escalations is not None
                      and len(escalations) >= max_escalations)
            # escalate only while an iteration remains to act on it — an
            # escalation recorded with no capacity to correct would
            # overcount the telemetry vs factorizations actually done
            if nxt <= ladder.index(target_tier) and it < max_iters \
                    and not capped:
                escalations.append({
                    "iteration": it, "from": factor_tier,
                    "to": ladder[nxt],
                    "ratio": berr / prev_berr
                    if (finite and prev_berr) else float("inf"),
                })
                factor_tier = ladder[nxt]
                if not finite:
                    # the iterate (and its residual) are poisoned: restart
                    # from the best finite iterate and re-measure
                    x = best[1] if best is not None else mp.zeros(
                        b_t.shape, target_tier,
                        dtype=mp.limbs(b_t)[0].dtype)
                    prev_berr = None
                    continue
                # finite stagnation: r is still valid — reuse it with the
                # new rung's correction
            else:
                # best-effort stop: name WHY refinement gave up, in
                # precedence order — a cap with rungs left is the caller's
                # decision ("escalation-capped"); the ladder top is the
                # arithmetic's floor ("ladder-exhausted"); otherwise only
                # the iteration budget ran out
                if capped and nxt <= ladder.index(target_tier):
                    hazard("escalation-capped", berr)
                elif nxt > ladder.index(target_tier):
                    hazard("ladder-exhausted", berr)
                else:
                    hazard("iteration-budget", berr)
                break
        x = _correct_step(get_fac(factor_tier), r, x,
                          factor_tier=factor_tier, target_tier=target_tier,
                          assume=assume)
        x_measured = False
        prev_berr = berr

    if x_measured:
        final_berr = history[-1] if history else float("inf")
    else:
        # max_iters exhausted right after a correction: the final iterate
        # was never measured (it could even be NaN from a broken rung) —
        # measure it once so final_backward_error describes the RETURNED x
        _, final_berr = measure(x)
    if best is not None and not (final_berr <= best[0]):
        x = best[1]  # a diverged last step never worsens the returned x
        final_berr = best[0]
    if not converged and not hazards:
        # the while condition (not a break) ended the loop: the budget ran
        # out mid-ladder — every non-converged solve reports a hazard
        hazard("iteration-budget", final_berr)
    if vector_rhs:
        x = mp.map_limbs(lambda l: l[..., 0], x)
    info = RefinementInfo(
        converged=converged, iterations=it, target_tier=target_tier,
        tol=float(tol), backward_errors=history, factor_tiers=tiers_hist,
        escalations=escalations,
        factorizations={t: c for t, c in fac_counts.items() if c},
        stagnations=stagnations, final_backward_error=final_berr,
        hazards=hazards,
    )
    return x, info


def rgesv(a, b, *, factor_tier: Optional[str] = None,
          target_tier: Optional[str] = None, assume: str = "gen",
          max_iters: int = 40, tol: Optional[float] = None,
          stagnation_ratio: float = 0.25, block: int = 32,
          max_escalations: Optional[int] = None,
          ladder: Optional[Tuple[str, ...]] = None,
          plan=None, **plan_overrides):
    """Solve A x = b by factor-cheap / refine-at-target iteration.

    ``a``: (n, n) — an f64 array or a dd/td/qd value; ``b``: (n,),
    (n, nrhs), or batched (..., n, nrhs) (the residual GEMM rides the
    engine's vmapped path; a ``mesh=`` override distributes it SUMMA-style
    over a 1-D or 2-D device mesh, composing with batching in the same
    call).  The system is factored once at ``factor_tier`` (default: the
    ladder's first rung); each iteration computes r = b - A x at
    ``target_tier`` (default: the tier of ``a``, or dd for plain arrays)
    as ONE engine call and back-substitutes the correction through the
    cheap factorization.  When a step fails to cut the per-column backward
    error ‖r‖ / (‖A‖·‖x‖ + ‖b‖) below ``stagnation_ratio`` (default 0.25)
    of the previous one, the factorization escalates one rung up
    ``ladder`` (default f64 -> dd -> td -> qd, capped at the target tier)
    and refinement continues; at the ladder top it stops at the tier's
    genuine floor.

    ``ladder`` overrides the rung sequence: any strictly-ascending tuple
    of tiers containing the factor and target tiers, e.g.
    ``("f64", "dd", "qd")`` for the pre-td climb or ``("dd", "td")`` to
    pin both ends.  The default ladder's td rung matters exactly when
    cond(A) sits between 1/u_dd (~1e32) and 1/u_td (~1e48): dd stalls
    there, and without td the old ladder paid for a qd factorization that
    td-grade arithmetic already covers.

    ``assume="pos"`` factors via Cholesky (the SDP Schur solve's path).
    ``max_escalations`` bounds the ladder climb: after that many
    escalations a stagnating solve stops with a best-effort x and a
    ``{"kind": "escalation-capped", ...}`` entry in ``info.hazards``
    instead of refactoring at the next rung (``max_escalations=0`` pins
    the starting rung).  Returns ``(x, info)`` with ``x`` at the target
    tier and ``info`` a :class:`RefinementInfo` (per-iteration backward
    errors, rungs, escalations, factorization counts, hazards).
    """
    if assume not in ("gen", "pos"):
        raise ValueError(f"assume must be 'gen' or 'pos', got {assume!r}")
    return _refine(a, b, factor_tier=factor_tier, target_tier=target_tier,
                   assume=assume, factorization=None, max_iters=max_iters,
                   tol=tol, stagnation_ratio=stagnation_ratio, block=block,
                   max_escalations=max_escalations, ladder=ladder,
                   plan=plan, plan_overrides=plan_overrides)


def rposv(a, b, **kwargs):
    """SPD convenience wrapper: ``rgesv(..., assume="pos")``."""
    kwargs.setdefault("assume", "pos")
    return rgesv(a, b, **kwargs)


def lu_solve_refined(a, lu, piv, b, *, target_tier: Optional[str] = None,
                     max_iters: int = 40, tol: Optional[float] = None,
                     stagnation_ratio: float = 0.25, block: int = 32,
                     max_escalations: Optional[int] = None,
                     ladder: Optional[Tuple[str, ...]] = None,
                     plan=None, **plan_overrides):
    """Refinement-backed ``lu_solve``: reuse an existing ``rgetrf`` output.

    The factorization's own tier (inferred from ``lu``) is the starting
    rung; escalation past it re-factors ``a`` as usual (bounded by
    ``max_escalations`` and walking ``ladder``, see :func:`rgesv`).
    ``a`` must be the matrix that was factored.
    """
    return _refine(a, b, factor_tier=mp.precision_of(lu),
                   target_tier=target_tier, assume="gen",
                   factorization=(lu, piv), max_iters=max_iters, tol=tol,
                   stagnation_ratio=stagnation_ratio, block=block,
                   max_escalations=max_escalations, ladder=ladder,
                   plan=plan, plan_overrides=plan_overrides)


def cholesky_solve_refined(a, l, b, *, target_tier: Optional[str] = None,
                           max_iters: int = 40, tol: Optional[float] = None,
                           stagnation_ratio: float = 0.25, block: int = 32,
                           max_escalations: Optional[int] = None,
                           ladder: Optional[Tuple[str, ...]] = None,
                           plan=None, **plan_overrides):
    """Refinement-backed ``cholesky_solve``: reuse an ``rpotrf`` factor."""
    return _refine(a, b, factor_tier=mp.precision_of(l),
                   target_tier=target_tier, assume="pos",
                   factorization=l, max_iters=max_iters, tol=tol,
                   stagnation_ratio=stagnation_ratio, block=block,
                   max_escalations=max_escalations, ladder=ladder,
                   plan=plan, plan_overrides=plan_overrides)
