"""Accuracy regression gate: the precision ladder must hold its bit budget.

Pins each engine tier's observed relative error on the exact-rational
Hilbert GEMM (core/accuracy.py — the same computation bench_accuracy emits
to BENCH_ACCURACY.json): dd must stay within 2^-100, qd within 2^-190.
A regression in the EFT chains, the renormalization sweeps, or the engine's
pad/dispatch plumbing shows up here as lost bits long before it corrupts an
end-to-end SDP solve.
"""

import json

import pytest

from repro.core.accuracy import GATES, write_accuracy_json


@pytest.fixture(scope="module")
def accuracy_doc(tmp_path_factory):
    path = tmp_path_factory.mktemp("accuracy") / "BENCH_ACCURACY.json"
    return write_accuracy_json(str(path), n=16), path


def test_dd_tier_holds_2_pow_minus_100(accuracy_doc):
    doc, _ = accuracy_doc
    assert doc["tiers"]["dd"]["rel_err"] <= 2.0 ** -100


def test_qd_tier_holds_2_pow_minus_190(accuracy_doc):
    doc, _ = accuracy_doc
    assert doc["tiers"]["qd"]["rel_err"] <= 2.0 ** -190


def test_artifact_schema_round_trips(accuracy_doc):
    doc, path = accuracy_doc
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == "repro-accuracy/v1"
    assert set(on_disk["tiers"]) == set(GATES)
    for tier, row in on_disk["tiers"].items():
        assert row["passes"] is True, (tier, row)
        assert row["gate"] == GATES[tier]
