"""Accuracy regression gate: the precision ladder must hold its bit budget.

Pins each engine tier's observed relative error on the exact-rational
Hilbert GEMM (core/accuracy.py — the same computation bench_accuracy emits
to BENCH_ACCURACY.json): dd must stay within 2^-100, td within 2^-150,
qd within 2^-190.  The gate runs per backend (GATED_BACKENDS): the engine
default (xla), the diagonal-grouped whole-K Ozaki path (dd/td), and the
fused per-slab ``ozaki-pallas`` kernel (every tier) — so a lost bit in
the count-generic renorm chains, the slice-grid ladder, the grouped
native summation, or the engine's pad/dispatch plumbing shows up here
long before it corrupts an end-to-end SDP solve.
"""

import json

import pytest

from repro.core.accuracy import GATED_BACKENDS, GATES, write_accuracy_json


@pytest.fixture(scope="module")
def accuracy_doc(tmp_path_factory):
    path = tmp_path_factory.mktemp("accuracy") / "BENCH_ACCURACY.json"
    return write_accuracy_json(str(path), n=16), path


def test_dd_tier_holds_2_pow_minus_100(accuracy_doc):
    doc, _ = accuracy_doc
    assert doc["tiers"]["dd"]["rel_err"] <= 2.0 ** -100


def test_td_tier_holds_2_pow_minus_150(accuracy_doc):
    doc, _ = accuracy_doc
    assert doc["tiers"]["td"]["rel_err"] <= 2.0 ** -150


def test_qd_tier_holds_2_pow_minus_190(accuracy_doc):
    doc, _ = accuracy_doc
    assert doc["tiers"]["qd"]["rel_err"] <= 2.0 ** -190


@pytest.mark.parametrize("backend,tier", [
    (be, tier) for be, tiers in GATED_BACKENDS.items() for tier in tiers])
def test_backend_tier_holds_its_gate(accuracy_doc, backend, tier):
    doc, _ = accuracy_doc
    row = doc["backends"][backend][tier]
    assert row["rel_err"] <= GATES[tier], (backend, tier, row)


def test_artifact_schema_round_trips(accuracy_doc):
    doc, path = accuracy_doc
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == "repro-accuracy/v2"
    assert set(on_disk["tiers"]) == set(GATES)
    assert set(on_disk["backends"]) == set(GATED_BACKENDS)
    for tier, row in on_disk["tiers"].items():
        assert row["passes"] is True, (tier, row)
        assert row["gate"] == GATES[tier]
    for be, tiers in on_disk["backends"].items():
        assert set(tiers) == set(GATED_BACKENDS[be])
        for tier, row in tiers.items():
            assert row["passes"] is True, (be, tier, row)
