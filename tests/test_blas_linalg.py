"""Rgemm API + blocked LU / TRSM / Cholesky accuracy tests (paper §III, §V-A)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dd
from repro.core.blas import rgemm, rsyrk, transpose
from repro.core.linalg import (
    cholesky_solve,
    lu_solve,
    rgetrf,
    rgetrf2,
    rpotrf,
    rtrsm,
)
from repro.kernels.ref import ddgemm_ref


def _from_np(a):
    return dd.from_float(jnp.asarray(a))


def _err(got: dd.DD, want_np):
    return float(np.abs(np.asarray(dd.to_float(got), np.float64) - want_np).max())


def _dd_resid(got: dd.DD, want: dd.DD):
    return float(np.abs(
        (np.asarray(got.hi, np.float64) - np.asarray(want.hi, np.float64))
        + (np.asarray(got.lo, np.float64) - np.asarray(want.lo, np.float64))
    ).max())


class TestRgemm:
    def test_plain(self):
        rng = np.random.default_rng(0)
        a, b = rng.standard_normal((8, 12)), rng.standard_normal((12, 8))
        got = rgemm("n", "n", 1.0, _from_np(a), _from_np(b), 0.0)
        assert _err(got, a @ b) < 1e-13

    def test_transposes(self):
        rng = np.random.default_rng(1)
        a, b = rng.standard_normal((12, 8)), rng.standard_normal((8, 12))
        got = rgemm("t", "t", 1.0, _from_np(a), _from_np(b), 0.0)
        assert _err(got, a.T @ b.T) < 1e-13

    def test_alpha_beta_epilogue(self):
        rng = np.random.default_rng(2)
        a, b, c = (rng.standard_normal((6, 6)) for _ in range(3))
        got = rgemm("n", "n", 2.5, _from_np(a), _from_np(b), -0.5, _from_np(c))
        want = 2.5 * (a @ b) - 0.5 * c
        assert _err(got, want) < 1e-13
        # DD-accuracy: against the DD oracle with DD epilogue
        prod = ddgemm_ref(_from_np(a), _from_np(b))
        want_dd = dd.add(dd.mul(dd.from_float(jnp.asarray(2.5)), prod),
                         dd.mul(dd.from_float(jnp.asarray(-0.5)), _from_np(c)))
        assert _dd_resid(got, want_dd) < 1e-28

    def test_dd_alpha(self):
        rng = np.random.default_rng(3)
        a, b = rng.standard_normal((4, 4)), rng.standard_normal((4, 4))
        alpha = dd.div(dd.from_float(jnp.asarray(1.0)), dd.from_float(jnp.asarray(3.0)))
        got = rgemm("n", "n", alpha, _from_np(a), _from_np(b), 0.0)
        assert _err(got, (a @ b) / 3.0) < 1e-13

    def test_backends_agree(self):
        rng = np.random.default_rng(4)
        a, b = rng.standard_normal((16, 24)), rng.standard_normal((24, 16))
        outs = [
            rgemm("n", "n", 1.0, _from_np(a), _from_np(b), 0.0, backend=be)
            for be in ("ozaki", "pallas", "xla", "ref")
        ]
        for o in outs[1:]:
            assert _dd_resid(o, outs[0]) < 1e-27

    def test_rsyrk(self):
        rng = np.random.default_rng(5)
        a = rng.standard_normal((6, 9))
        got = rsyrk("l", "n", 1.0, _from_np(a), 0.0)
        assert _err(got, a @ a.T) < 1e-13
        got_t = rsyrk("l", "t", 1.0, _from_np(a), 0.0)
        assert _err(got_t, a.T @ a) < 1e-13


class TestLU:
    @pytest.mark.parametrize("n,block", [(16, 16), (24, 8), (48, 16), (33, 8)])
    def test_rgetrf_reconstructs(self, n, block):
        rng = np.random.default_rng(n)
        a_np = rng.random((n, n))  # paper §V-A: entries in [0, 1)
        a = _from_np(a_np)
        lu, piv = rgetrf(a, block=block)
        lu_np = np.asarray(dd.to_float(lu), np.float64)
        l = np.tril(lu_np, -1) + np.eye(n)
        u = np.triu(lu_np)
        # P A = L U  (apply interchanges to A)
        pa = a_np.copy()
        for j, p in enumerate(piv):
            pa[[j, p]] = pa[[p, j]]
        assert np.abs(l @ u - pa).max() < 1e-12 * n

    def test_rgetrf_dd_accuracy(self):
        # residual measured in DD: reconstruct L@U in DD and compare to P A
        n = 24
        rng = np.random.default_rng(7)
        a_np = rng.random((n, n))
        a = _from_np(a_np)
        lu, piv = rgetrf(a, block=8)
        lu_np_hi, lu_np_lo = np.asarray(lu.hi), np.asarray(lu.lo)
        tril_mask = np.tril(np.ones((n, n)), -1)
        l = dd.DD(jnp.asarray(lu_np_hi * tril_mask + np.eye(n)),
                  jnp.asarray(lu_np_lo * tril_mask))
        u = dd.DD(jnp.asarray(np.triu(lu_np_hi)), jnp.asarray(np.triu(lu_np_lo)))
        prod = ddgemm_ref(l, u)
        pa = a_np.copy()
        for j, p in enumerate(piv):
            pa[[j, p]] = pa[[p, j]]
        resid = np.abs(np.asarray(prod.hi) + np.asarray(prod.lo) - pa).max()
        # binary128-class residual: far below f64 eps (paper's E_L1 ~ 1e-31..-28)
        assert resid < 1e-26

    def test_pivoting_matches_numpy_growth(self):
        # partial pivoting keeps |L| <= 1
        n = 32
        rng = np.random.default_rng(11)
        a = _from_np(rng.standard_normal((n, n)))
        lu, piv = rgetrf(a, block=8)
        l_np = np.tril(np.asarray(dd.to_float(lu)), -1)
        assert np.abs(l_np).max() <= 1.0 + 1e-12

    def test_lu_solve(self):
        n = 20
        rng = np.random.default_rng(13)
        a_np = rng.random((n, n)) + n * np.eye(n)
        x_np = rng.standard_normal((n, 3))
        b_np = a_np @ x_np
        lu, piv = rgetrf(_from_np(a_np), block=8)
        x = lu_solve(lu, piv, _from_np(b_np))
        assert _err(x, x_np) < 1e-10


class TestTrsmChol:
    def test_trsm_lower_unit(self):
        n = 16
        rng = np.random.default_rng(17)
        l_np = np.tril(rng.standard_normal((n, n)), -1) + np.eye(n)
        x_np = rng.standard_normal((n, 5))
        b_np = l_np @ x_np
        x = rtrsm(_from_np(l_np), _from_np(b_np), lower=True, unit_diag=True)
        assert _err(x, x_np) < 1e-11

    def test_trsm_upper(self):
        n = 16
        rng = np.random.default_rng(19)
        u_np = np.triu(rng.standard_normal((n, n))) + 3 * np.eye(n)
        x_np = rng.standard_normal((n, 5))
        b_np = u_np @ x_np
        x = rtrsm(_from_np(u_np), _from_np(b_np), lower=False, unit_diag=False)
        assert _err(x, x_np) < 1e-11

    def test_trsm_transpose(self):
        n = 12
        rng = np.random.default_rng(23)
        l_np = np.tril(rng.standard_normal((n, n))) + 3 * np.eye(n)
        x_np = rng.standard_normal((n, 4))
        b_np = l_np.T @ x_np
        x = rtrsm(_from_np(l_np), _from_np(b_np), lower=True, unit_diag=False,
                  transpose_a=True)
        assert _err(x, x_np) < 1e-11

    def test_potrf_and_solve(self):
        n = 20
        rng = np.random.default_rng(29)
        g = rng.standard_normal((n, n))
        a_np = g @ g.T + n * np.eye(n)
        l = rpotrf(_from_np(a_np))
        l_np = np.asarray(dd.to_float(l))
        assert np.abs(l_np @ l_np.T - a_np).max() < 1e-11
        # DD-level residual of the factorization
        prod = ddgemm_ref(l, transpose(l))
        resid = np.abs(np.asarray(prod.hi) + np.asarray(prod.lo) - a_np).max()
        assert resid < 1e-25
        x_np = rng.standard_normal((n, 2))
        b_np = a_np @ x_np
        x = cholesky_solve(l, _from_np(b_np))
        assert _err(x, x_np) < 1e-9
