"""Chaos suite: every FaultPlan injection class ends in a typed hazard or
an oracle-conformant recovery — never a silent wrong answer.

Each test exercises one injection class end to end through the production
stack (engine dispatch, failover loop, plan cache, SUMMA K-loop,
refinement driver) and records a per-class verdict; the module teardown
writes them to ``CHAOS_REPORT.json`` — the hazard-report artifact CI's
``chaos`` job uploads.  Run via ``make chaos-tests`` (forces 4 host
devices so the SUMMA panel-loss cell gets a real 2x2 mesh).
"""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import mp
from repro.kernels.ref import ddgemm_ref
from repro.runtime import faults
from repro.runtime.faults import (BackendExecutionError,
                                  BackendFailoverWarning, FaultPlan,
                                  InjectedFault, Injection,
                                  NumericalHazardError)

pytestmark = pytest.mark.chaos

N = 12
DD_TOL = 2.0 ** -96

VERDICTS = {}


def verdict(cls: str, outcome: str, **detail):
    assert outcome in ("detected", "recovered")
    VERDICTS[cls] = {"outcome": outcome, **detail}


@pytest.fixture(scope="module", autouse=True)
def chaos_report():
    yield
    with open("CHAOS_REPORT.json", "w") as f:
        json.dump({"schema": "repro-chaos/v1", "classes": VERDICTS}, f,
                  indent=1, default=str)


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(rng.standard_normal(shape)), "dd")


def _max_dev(got, want) -> float:
    return float(np.abs(np.asarray(mp.to_float(got))
                        - np.asarray(mp.to_float(want))).max())


# --------------------------------------------------------------------------
# class: limb flip (finite-but-wrong -> only the full shadow check sees it)
# --------------------------------------------------------------------------


def test_limb_flip_detected_by_full_check(tmp_cache):
    a, b = _rand((N, N), 1), _rand((N, N), 2)
    plan = gemm.make_plan(N, N, N, backend="xla", use_cache=False)
    flip = Injection("gemm.out", kind="limb_flip", limb=0, scale=2.0)
    # first, the threat model: under check="none" the flipped limb is
    # FINITE and WRONG — the silent corruption the shadow product exists
    # to catch
    with faults.inject(FaultPlan(seed=3, injections=(flip,))):
        out = gemm.execute(plan, a, b, check="none")
        assert faults.fired()
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in mp.limbs(out))
    assert _max_dev(out, ddgemm_ref(a, b)) > 1e-3
    # the same fault under check="full" raises the typed mismatch hazard
    with faults.inject(FaultPlan(seed=3, injections=(flip,))):
        with pytest.raises(NumericalHazardError) as ei:
            gemm.execute(plan, a, b, check="full")
        assert [f["site"] for f in faults.fired()] == ["gemm.out"]
    assert ei.value.kind == "mismatch"
    assert ei.value.operand == "output"
    verdict("limb-flip", "detected", error=ei.value.report)


# --------------------------------------------------------------------------
# class: NaN / Inf tile poison
# --------------------------------------------------------------------------


def test_nan_poison_detected_or_propagates(tmp_cache):
    a, b = _rand((N, N), 4), _rand((N, N), 5)
    plan = gemm.make_plan(N, N, N, backend="xla", use_cache=False)
    poison = Injection("gemm.a", kind="nan", frac=0.1)
    with faults.inject(FaultPlan(seed=1, injections=(poison,))):
        with pytest.raises(NumericalHazardError) as ei:
            gemm.execute(plan, a, b, check="finite")
    assert ei.value.operand == "A" and ei.value.kind == "nan"
    assert ei.value.nan_count == max(1, int(0.1 * N * N))
    # the same poison under check="none" propagates IEEE-style
    with faults.inject(FaultPlan(seed=1, injections=(poison,))):
        out = gemm.execute(plan, a, b, check="none")
    assert bool(jnp.any(jnp.isnan(mp.limbs(out)[0])))
    verdict("nan-poison", "detected", error=ei.value.report)


def test_inf_poison_of_output_detected(tmp_cache):
    a, b = _rand((N, N), 6), _rand((N, N), 7)
    plan = gemm.make_plan(N, N, N, backend="ozaki", use_cache=False)
    with faults.inject(FaultPlan(seed=2, injections=(
            Injection("gemm.out", kind="inf", frac=0.05),))):
        with pytest.raises(NumericalHazardError) as ei:
            gemm.execute(plan, a, b, check="finite")
    assert ei.value.operand == "output" and ei.value.kind == "inf"
    verdict("inf-poison", "detected", error=ei.value.report)


# --------------------------------------------------------------------------
# class: autotune-cache corruption
# --------------------------------------------------------------------------


def test_cache_corruption_recovered(tmp_cache):
    tmp_cache.put("some/tuned/key", {"bm": 16, "bn": 16, "bk": 8})
    with faults.inject(FaultPlan(injections=(
            Injection("cache.file", kind="truncate"),))):
        assert faults.chaos_cache(tmp_cache.path) == ["truncate"]
    # a fresh reader warns once, degrades to heuristics, and the GEMM
    # still answers correctly
    fresh = gemm.PlanCache(tmp_cache.path)
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert fresh.get("some/tuned/key") is None
    gemm.set_default_cache(fresh)
    a, b = _rand((N, N), 8), _rand((N, N), 9)
    out = gemm.matmul(a, b, backend="ozaki")
    assert _max_dev(out, ddgemm_ref(a, b)) < N * DD_TOL
    # garbage and delete corruption degrade the same way (no warning on
    # delete: a missing file is the normal cold start)
    for kind in ("garbage", "delete"):
        tmp_cache.put("some/tuned/key", {"bm": 16})
        with faults.inject(FaultPlan(injections=(
                Injection("cache.file", kind=kind),))):
            assert faults.chaos_cache(tmp_cache.path) == [kind]
        reader = gemm.PlanCache(tmp_cache.path)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            assert reader.get("some/tuned/key") is None
    verdict("cache-corruption", "recovered",
            kinds=["truncate", "garbage", "delete"])


def test_killed_cache_writer_leaves_old_file_intact(tmp_cache, tmp_path,
                                                    monkeypatch):
    import repro.gemm.cache as cache_mod

    tmp_cache.put("k1", {"bm": 16})

    def dying_dump(obj, f, **kw):
        f.write('{"k2": {"bm":')  # half an entry, then the "kill"
        raise InjectedFault("cache.write")

    monkeypatch.setattr(cache_mod.json, "dump", dying_dump)
    with pytest.raises(InjectedFault):
        tmp_cache.put("k2", {"bm": 32})
    monkeypatch.undo()
    # atomic write protocol: the visible file is the OLD complete one —
    # never the torn write — and the temp file was cleaned up
    assert [p.name for p in tmp_path.glob("*.tmp")] == []
    fresh = gemm.PlanCache(tmp_cache.path)
    assert fresh.get("k1") == {"bm": 16}
    assert fresh.get("k2") is None
    verdict("cache-writer-kill", "recovered")


# --------------------------------------------------------------------------
# class: backend execution failure -> failover + quarantine
# --------------------------------------------------------------------------


def test_backend_failure_fails_over_and_quarantines(tmp_cache):
    a, b = _rand((N, N), 10), _rand((N, N), 11)
    want = ddgemm_ref(a, b)
    platform = jax.default_backend()
    with faults.inject(FaultPlan(injections=(
            Injection("backend.ozaki-pallas", kind="raise", times=5),))):
        with pytest.warns(BackendFailoverWarning, match="ozaki"):
            out = gemm.matmul(a, b, backend="ozaki-pallas")
        assert _max_dev(out, want) < N * DD_TOL
        assert len(faults.fired()) == 1
        # the failure was recorded: repeat calls reroute at PLAN time, so
        # the doomed backend is not re-attempted (the injection, still
        # armed 4 more times, does not fire again)
        assert gemm.quarantined(platform, "ozaki-pallas") is not None
        with pytest.warns(BackendFailoverWarning, match="quarantined"):
            plan2 = gemm.make_plan(N, N, N, backend="ozaki-pallas")
        assert plan2.backend != "ozaki-pallas"
        out2 = gemm.execute(plan2, a, b)
        assert _max_dev(out2, want) < N * DD_TOL
        assert len(faults.fired()) == 1
    # the documented remedy lifts the bench
    assert gemm.clear_quarantine() >= 1
    assert gemm.quarantined(platform, "ozaki-pallas") is None
    verdict("backend-failure", "recovered",
            fallback=plan2.backend, quarantined="ozaki-pallas")


def test_whole_chain_failure_raises_typed_receipt(tmp_cache):
    a, b = _rand((N, N), 12), _rand((N, N), 13)
    with faults.inject(FaultPlan(injections=tuple(
            Injection(f"backend.{be}", kind="raise", times=5)
            for be in ("ozaki-pallas", "ozaki", "xla")))):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", BackendFailoverWarning)
            with pytest.raises(BackendExecutionError) as ei:
                gemm.matmul(a, b, backend="ozaki-pallas")
    # the receipt names every rung actually tried, in order
    assert [at[0] for at in ei.value.attempts] == \
        ["ozaki-pallas", "ozaki", "xla"]
    assert all("InjectedFault" in at[1] for at in ei.value.attempts)


# --------------------------------------------------------------------------
# class: SUMMA panel loss (finite-but-wrong on a real 2x2 mesh)
# --------------------------------------------------------------------------


@pytest.mark.sharding
@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs 4 devices (run under make chaos-tests)")
def test_summa_panel_loss_detected_by_full_check(tmp_cache):
    from jax.sharding import Mesh

    n = 32
    a, b = _rand((n, n), 14), _rand((n, n), 15)
    want = ddgemm_ref(a, b)
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                ("rows", "cols"))
    kw = dict(backend="xla", mesh=mesh, k_panel=8, use_cache=False)
    with faults.inject(FaultPlan(injections=(
            Injection("summa.panel.a", kind="zero", step=1),))):
        with pytest.raises(NumericalHazardError) as ei:
            gemm.matmul(a, b, check="full", **kw)
        assert [f["site"] for f in faults.fired()] == ["summa.panel.a"]
    # a zeroed K-panel is finite but wrong: only the shadow check sees it
    assert ei.value.kind == "mismatch"
    # leaving the plan's scope drops the faulty trace: the same sharded
    # call retraces cleanly and conforms
    got = gemm.matmul(a, b, check="full", **kw)
    assert _max_dev(got, want) < n * DD_TOL
    verdict("summa-panel-loss", "detected", error=ei.value.report)


# --------------------------------------------------------------------------
# class: mid-refinement kill -> run_with_restarts recovery with backoff
# --------------------------------------------------------------------------


def test_mid_refinement_kill_recovered_with_backoff(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.runtime.failover import restart_backoff, run_with_restarts
    from repro.solve.refine import rgesv

    n = 8
    rng = np.random.default_rng(16)
    a_np = rng.standard_normal((n, n)) + n * np.eye(n)
    b_np = rng.standard_normal((n, 1))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    restarts, waits = [], []

    def make_state(restore_step):
        if restore_step is None:
            return {"solves": jnp.zeros(())}, 0
        state, meta = mgr.restore({"solves": jnp.zeros(())})
        return state, meta["step"]

    def step_fn(state, step):
        x, info = rgesv(a_np, b_np, factor_tier="f64", target_tier="dd",
                        backend="xla")
        assert info.converged
        # measured in f64, so floored at f64 roundoff; the dd-grade
        # backward error is already gated by info.converged
        resid = np.abs(a_np @ np.asarray(mp.to_float(x)) - b_np).max()
        assert resid < 1e-12
        return {"solves": state["solves"] + 1}

    with faults.inject(FaultPlan(seed=9, injections=(
            Injection("refine.kill", kind="raise", step=1, times=1),))):
        state, step, failures = run_with_restarts(
            make_state, step_fn, mgr, total_steps=3, checkpoint_every=1,
            max_failures=3, backoff_base=0.001, backoff_jitter=0.5, seed=9,
            on_restart=lambda s, f, w: restarts.append((s, f, w)),
            sleep=waits.append)
        log = faults.fired()
    # the kill fired exactly once, inside refinement iteration 1 ...
    assert [(f["site"], f["iteration"]) for f in log] == [("refine.kill", 1)]
    # ... run_with_restarts absorbed it, backed off the seeded wait, and
    # the replayed step solved to convergence
    assert failures == 1 and step == 3
    assert float(state["solves"]) == 3
    assert waits == [restart_backoff(1, base=0.001, jitter=0.5, seed=9)]
    assert restarts == [(0, 1, waits[0])] and waits[0] > 0.0
    verdict("refine-kill", "recovered", waited=waits[0])


def test_escalation_cap_yields_best_effort_plus_hazard_report():
    from repro.core.accuracy import hilbert_f64
    from repro.solve.refine import rgesv

    # Hilbert n=14 stagnates on the f64 rung and needs one escalation to
    # converge (see test_solve.py); capping escalations at 0 must yield a
    # best-effort result WITH a hazard report, not an exception and not a
    # silent non-converged success
    n = 14
    h = hilbert_f64(n)
    b = h @ np.ones((n, 1))
    x, info = rgesv(h, b, factor_tier="f64", target_tier="dd",
                    backend="xla", max_iters=25, max_escalations=0)
    assert not info.converged
    assert not info.escalations
    assert [hz["kind"] for hz in info.hazards] == ["escalation-capped"]
    hz = info.hazards[0]
    assert hz["rung"] == "f64" and hz["target"] == "dd"
    assert hz["finite"] and np.isfinite(info.final_backward_error)
    assert np.isfinite(np.asarray(mp.to_float(x))).all()
    # the uncapped run converges on the same data — the cap is the only
    # difference between recovery and the hazard report
    x2, info2 = rgesv(h, b, factor_tier="f64", target_tier="dd",
                      backend="xla", max_iters=25)
    assert info2.converged and not info2.hazards
    verdict("escalation-cap", "recovered",
            hazard=info.hazards[0], capped_berr=info.final_backward_error)
