"""Cross-backend conformance matrix for the precision-generic GEMM engine.

One parametrized sweep over (backend x precision x shape x epilogue)
against the per-tier ``ref`` oracle (kernels/ref.py), with per-tier ulp
bounds.  Shapes include non-square and odd-K cases, so padding/clamping
in the engine is exercised at every limb count (dd/td/qd); the alpha/beta
cells run the full Rgemm epilogue with non-representable tier scalars
(1/3, -1/7).

The SUMMA axis runs the same product conformance over mesh topologies
(1x1, 1xN, Nx1, 2x2 — the 2-D SUMMA distribution layer) against the
qd-direct oracle at both tiers, plus the epilogue/batched cells; cells
needing more devices than the process has skip, and CI's ``sharding`` job
forces 4 host devices so every cell runs.

The solver axis extends the same discipline to ``repro.solve``: every
(factor_tier x target_tier) rung combination, on the plain, batched and
row-sharded multi-RHS paths, is conformance-checked against a qd-direct
oracle (full qd ``rgetrf`` + ``lu_solve`` — the most accurate solve the
repo can produce), plus refinement-convergence invariants (monotone
non-increasing backward error; escalation exactly on stagnation).

This is the test CI's ``conformance`` job runs on CPU interpret mode —
every cell of the support matrix must agree with its oracle before a
backend/tier combination is considered live.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import mp
from repro.core.accuracy import max_rel_err as _rel_err
from repro.core.blas import rgemm
from repro.core.linalg import lu_solve, rgetrf
from repro.kernels.ref import ddgemm_ref, qdgemm_ref, tdgemm_ref
from repro.solve import rgesv

# per-tier unit roundoff of one engine FMA (dd: two_prod slack dominates;
# td/qd: the O(eps^k) renormalization truncation)
ULP = {"dd": 2.0 ** -104, "td": 2.0 ** -155, "qd": 2.0 ** -205}
REF = {"dd": ddgemm_ref, "td": tdgemm_ref, "qd": qdgemm_ref}

# the support matrix: whole-K ozaki has no qd tier (rejected below,
# separately); every other backend serves every tier, and ozaki serves
# dd and td
CELLS = [(be, "dd") for be in ("pallas", "ozaki", "ozaki-pallas",
                               "xla", "ref")] + \
        [(be, "td") for be in ("pallas", "ozaki", "ozaki-pallas",
                               "xla", "ref")] + \
        [(be, "qd") for be in ("pallas", "ozaki-pallas", "xla", "ref")]

# square / non-square / odd-K (prime) so every backend pads and clamps
SHAPES = [(16, 16, 16), (13, 7, 9), (8, 33, 12)]


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(precision, shape, seed):
    """Random multi-limb operand with signal in every limb."""
    rng = np.random.default_rng(seed)
    out = mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)
    for scale in (2.0 ** -53, 2.0 ** -106, 2.0 ** -159)[: mp.nlimbs(out) - 1]:
        extra = mp.from_float(
            jnp.asarray(rng.standard_normal(shape) * scale), precision)
        out = mp.add(out, extra)
    return out


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("backend,precision", CELLS)
def test_product_matches_tier_oracle(backend, precision, m, k, n, tmp_cache):
    a = _rand(precision, (m, k), seed=m * 31 + k)
    b = _rand(precision, (k, n), seed=n * 17 + k)
    want = REF[precision](a, b)
    got = gemm.matmul(a, b, backend=backend)
    assert mp.precision_of(got) == precision
    assert _rel_err(got, want) < 16 * k * ULP[precision]


@pytest.mark.parametrize("backend,precision", CELLS)
def test_alpha_beta_epilogue_in_tier(backend, precision, tmp_cache):
    m, k, n = 9, 11, 6  # odd everything: padding + epilogue broadcast
    a = _rand(precision, (m, k), seed=1)
    b = _rand(precision, (k, n), seed=2)
    c = _rand(precision, (m, n), seed=3)
    one = mp.from_float(jnp.asarray(1.0), precision)
    third = mp.div(one, mp.from_float(jnp.asarray(3.0), precision))
    m_seventh = mp.div(mp.neg(one), mp.from_float(jnp.asarray(7.0), precision))
    got = rgemm("n", "n", third, a, b, m_seventh, c, backend=backend)
    prod = REF[precision](a, b)
    want = mp.add(mp.mul(mp.broadcast_to(third, prod.shape), prod),
                  mp.mul(mp.broadcast_to(m_seventh, c.shape), c))
    assert _rel_err(got, want) < 16 * k * ULP[precision]


@pytest.mark.parametrize("backend,precision", CELLS)
def test_batched_matches_looped_oracle(backend, precision, tmp_cache):
    a = _rand(precision, (3, 7, 5), seed=4)
    b = _rand(precision, (5, 8), seed=5)
    got = gemm.matmul(a, b, backend=backend)
    assert got.shape == (3, 7, 8)
    for i in range(3):
        want = REF[precision](a[i], b)
        assert _rel_err(got[i], want) < 16 * 5 * ULP[precision]


def test_transpose_flags_compose_with_tiers(tmp_cache):
    for precision in ("dd", "td", "qd"):
        a = _rand(precision, (7, 10), seed=6)   # op(A) = A^T: (10, 7)
        b = _rand(precision, (7, 4), seed=7)
        got = rgemm("t", "n", 1.0, a, b, 0.0, backend="xla")
        want = REF[precision](
            mp.map_limbs(lambda l: l.T, a), b)
        assert _rel_err(got, want) < 16 * 7 * ULP[precision]


def test_ozaki_has_no_qd_tier(tmp_cache):
    a = _rand("qd", (8, 8), seed=8)
    with pytest.raises(ValueError, match="ozaki"):
        gemm.matmul(a, a, backend="ozaki")


def test_mixed_tier_operands_rejected(tmp_cache):
    a = _rand("dd", (8, 8), seed=9)
    b = _rand("qd", (8, 8), seed=10)
    with pytest.raises(TypeError, match="tier"):
        gemm.matmul(a, b, backend="xla")


def test_plan_precision_must_match_operands(tmp_cache):
    plan = gemm.make_plan(8, 8, 8, backend="xla", precision="qd")
    a = _rand("dd", (8, 8), seed=11)
    with pytest.raises(ValueError, match="precision"):
        gemm.execute(plan, a, a)


# --------------------------------------------------------------------------
# solver axis: (factor_tier x target_tier) x (plain | batched | sharded)
# conformance-checked against the qd-direct oracle
# --------------------------------------------------------------------------

from repro.solve import LADDER_CELLS as SOLVER_CELLS  # noqa: E402

_SOLVER_N, _SOLVER_NRHS = 12, 2


@pytest.fixture(scope="module")
def solver_oracle():
    """qd-direct solve (full qd rgetrf + lu_solve): the accuracy ceiling."""
    rng = np.random.default_rng(31)
    a = rng.standard_normal((_SOLVER_N, _SOLVER_N)) + _SOLVER_N * np.eye(
        _SOLVER_N)
    b = rng.standard_normal((_SOLVER_N, _SOLVER_NRHS))
    a_qd = mp.from_float(jnp.asarray(a), "qd")
    b_qd = mp.from_float(jnp.asarray(b), "qd")
    lu, piv = rgetrf(a_qd, block=8)
    return a, b, lu_solve(lu, piv, b_qd)


@pytest.mark.solver
@pytest.mark.parametrize("mode", ["plain", "batched", "sharded"])
@pytest.mark.parametrize("factor_tier,target_tier", SOLVER_CELLS)
def test_solver_matches_qd_direct_oracle(factor_tier, target_tier, mode,
                                         solver_oracle, tmp_cache):
    a, b, x_oracle = solver_oracle
    kwargs = dict(factor_tier=factor_tier, target_tier=target_tier,
                  backend="xla")
    if mode == "sharded":
        from jax.sharding import Mesh

        kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("rows",))
    if mode == "batched":
        # 2x is a power of two: the scaled RHS (and hence its solution)
        # is exact at every tier, so the oracle scales exactly too
        got, info = rgesv(a, np.stack([b, 2.0 * b]), **kwargs)
        assert got.shape == (2, _SOLVER_N, _SOLVER_NRHS)
        cells = [(got[0], x_oracle),
                 (got[1], mp.mul_float(x_oracle, jnp.float64(2.0)))]
    else:
        got, info = rgesv(a, b, **kwargs)
        cells = [(got, x_oracle)]
    assert info.converged, (factor_tier, target_tier, mode,
                            info.backward_errors)
    # refinement must deliver the *target tier's* accuracy no matter how
    # cheap the factorization rung was
    for x, want in cells:
        err = _rel_err(mp.promote(x, "qd"), want)
        assert err < 64 * _SOLVER_N * ULP[target_tier], \
            (factor_tier, target_tier, mode, err)


@pytest.mark.solver
@pytest.mark.parametrize("factor_tier,target_tier", SOLVER_CELLS)
def test_refinement_backward_error_monotone(factor_tier, target_tier,
                                            solver_oracle, tmp_cache):
    a, b, _ = solver_oracle
    _, info = rgesv(a, b, factor_tier=factor_tier, target_tier=target_tier,
                    backend="xla")
    h = info.backward_errors
    assert all(later <= earlier for earlier, later in zip(h, h[1:])), h
    assert not info.escalations  # well-conditioned: no rung ever stagnates


@pytest.mark.solver
def test_escalation_fires_exactly_on_stagnation(tmp_cache):
    from repro.core.accuracy import hilbert_f64

    n = 14  # cond ~ 1e18: f64 corrections crawl, the dd rung finishes
    h = hilbert_f64(n)
    b = h @ np.ones((n, 1))
    _, info = rgesv(h, b, factor_tier="f64", target_tier="dd",
                    backend="xla", max_iters=25)
    assert info.converged
    assert [(e["from"], e["to"]) for e in info.escalations] == \
        [("f64", "dd")]
    # the escalation iteration is exactly the first stagnating one
    berrs = info.backward_errors
    it = info.escalations[0]["iteration"]
    assert berrs[it - 1] > 0.25 * berrs[it - 2]
    assert all(berrs[i] <= 0.25 * berrs[i - 1] for i in range(2, it - 1))


# --------------------------------------------------------------------------
# SUMMA axis: mesh topologies vs the qd-direct oracle, dd and qd
# --------------------------------------------------------------------------

# (rows, cols) topologies; cells needing more devices than the process has
# skip (CI's `sharding` job forces 4 host devices so every cell runs)
_MESHES = [(1, 1), (1, 2), (2, 1), (2, 2)]


def _mesh(rows: int, cols: int):
    from jax.sharding import Mesh

    if jax.device_count() < rows * cols:
        pytest.skip(f"needs {rows * cols} devices, have {jax.device_count()}")
    return Mesh(np.array(jax.devices()[: rows * cols]).reshape(rows, cols),
                ("rows", "cols"))


@pytest.mark.sharding
@pytest.mark.parametrize("rows,cols", _MESHES)
@pytest.mark.parametrize("precision", ["dd", "qd"])
def test_summa_matches_qd_direct_oracle(rows, cols, precision, tmp_cache):
    mesh = _mesh(rows, cols)
    m, k, n = 13, 23, 9  # odd everything: every dim pads against the mesh
    a = _rand(precision, (m, k), seed=60)
    b = _rand(precision, (k, n), seed=61)
    # qd-direct product: the most accurate GEMM the repo can produce —
    # climbing to qd is exact, so this bounds the dd cells' true error too
    want = qdgemm_ref(mp.promote(a, "qd"), mp.promote(b, "qd"))
    got = gemm.matmul(a, b, backend="xla", mesh=mesh, k_panel=8)
    assert mp.precision_of(got) == precision
    assert _rel_err(mp.promote(got, "qd"), want) < 16 * k * ULP[precision]


@pytest.mark.sharding
@pytest.mark.parametrize("rows,cols", _MESHES)
def test_summa_epilogue_and_batch_match_oracle(rows, cols, tmp_cache):
    mesh = _mesh(rows, cols)
    m, k, n = 13, 23, 9
    a = _rand("dd", (2, m, k), seed=62)  # batched + sharded, one call
    b = _rand("dd", (k, n), seed=63)
    c = _rand("dd", (m, n), seed=64)
    one = mp.from_float(jnp.asarray(1.0), "dd")
    third = mp.div(one, mp.from_float(jnp.asarray(3.0), "dd"))
    m7th = mp.div(mp.neg(one), mp.from_float(jnp.asarray(7.0), "dd"))
    got = rgemm("n", "n", third, a, b, m7th, c, backend="xla", mesh=mesh)
    assert got.shape == (2, m, n)
    for i in range(2):
        prod = ddgemm_ref(a[i], b)
        want = mp.add(mp.mul(mp.broadcast_to(third, prod.shape), prod),
                      mp.mul(mp.broadcast_to(m7th, c.shape), c))
        assert _rel_err(got[i], want) < 16 * k * ULP["dd"]


@pytest.mark.solver
@pytest.mark.sharding
def test_solver_multi_rhs_on_2d_mesh(solver_oracle, tmp_cache):
    # refined solves ride the SUMMA layer: batched multi-RHS residuals on
    # a 2-axis mesh (rows x RHS columns) through one engine call per step
    a, b, x_oracle = solver_oracle
    rows = 2 if jax.device_count() >= 2 else 1
    cols = 2 if jax.device_count() >= 4 else 1
    mesh = _mesh(rows, cols)
    got, info = rgesv(a, np.stack([b, 2.0 * b]), factor_tier="f64",
                      target_tier="dd", backend="xla", mesh=mesh)
    assert info.converged, info.backward_errors
    cells = [(got[0], x_oracle),
             (got[1], mp.mul_float(x_oracle, jnp.float64(2.0)))]
    for x, want in cells:
        assert _rel_err(mp.promote(x, "qd"), want) < \
            64 * _SOLVER_N * ULP["dd"]


# --------------------------------------------------------------------------
# ring-vs-psum: the ppermute ring panel schedule must be BIT-IDENTICAL to
# the legacy masked-psum broadcast (same panels, same fold order — PR 9's
# conformance gate for the comm rewrite), plus the uneven-K and k_stream
# exactness regressions
# --------------------------------------------------------------------------

# multi-device topologies only: on 1x1 both schedules degenerate to the
# same no-comm loop
_RING_MESHES = [(1, 2), (2, 1), (2, 2)]


def _limbs_equal(x, y):
    for lx, ly in zip(mp.limbs(x), mp.limbs(y)):
        np.testing.assert_array_equal(np.asarray(lx), np.asarray(ly))


def _comm_pair(m, k, n, mesh, precision="dd", **kw):
    return tuple(
        gemm.make_plan(m, k, n, backend="xla", precision=precision,
                       mesh=mesh, comm=comm, use_cache=False, **kw)
        for comm in ("ring", "psum"))


@pytest.mark.sharding
@pytest.mark.parametrize("rows,cols", _RING_MESHES)
@pytest.mark.parametrize("precision", ["dd", "qd"])
def test_ring_bit_identical_to_psum(rows, cols, precision, tmp_cache):
    mesh = _mesh(rows, cols)
    m, k, n = 13, 23, 9
    a = _rand(precision, (m, k), seed=70)
    b = _rand(precision, (k, n), seed=71)
    ring, psum = _comm_pair(m, k, n, mesh, precision, k_panel=8)
    _limbs_equal(gemm.execute(ring, a, b), gemm.execute(psum, a, b))


@pytest.mark.sharding
@pytest.mark.parametrize("rows,cols", _RING_MESHES)
def test_ring_epilogue_and_batched_bit_identical(rows, cols, tmp_cache):
    mesh = _mesh(rows, cols)
    m, k, n = 13, 23, 9
    a = _rand("dd", (2, m, k), seed=72)  # batched + sharded + epilogue
    b = _rand("dd", (k, n), seed=73)
    c = _rand("dd", (m, n), seed=74)
    ring, psum = _comm_pair(m, k, n, mesh, "dd", k_panel=8,
                            batch_shape=(2,))
    _limbs_equal(
        gemm.execute(ring, a, b, alpha=0.5, beta=-2.0, c=c),
        gemm.execute(psum, a, b, alpha=0.5, beta=-2.0, c=c))


@pytest.mark.sharding
@pytest.mark.parametrize("k,k_panel", [
    (23, 8),   # K not divisible by kp * lcm(Pr, Pc)
    (3, 8),    # K smaller than one panel
    (7, 16),   # K smaller than a panel round on every topology
])
def test_ring_uneven_k_bit_identical(k, k_panel, tmp_cache):
    mesh = _mesh(2, 2)
    m, n = 13, 9
    a = _rand("dd", (m, k), seed=75)
    b = _rand("dd", (k, n), seed=76)
    ring, psum = _comm_pair(m, k, n, mesh, "dd", k_panel=k_panel)
    got = gemm.execute(ring, a, b)
    _limbs_equal(got, gemm.execute(psum, a, b))
    want = qdgemm_ref(mp.promote(a, "qd"), mp.promote(b, "qd"))
    assert _rel_err(mp.promote(got, "qd"), want) < 16 * max(k, 8) * ULP["dd"]


@pytest.mark.sharding
@pytest.mark.parametrize("k,k_stream", [
    (23, 5),   # chunk not dividing K (and not panel-aligned: rounds up)
    (23, 8),   # chunk == panel depth
    (40, 16),  # several whole chunks + ragged tail
])
def test_k_stream_bit_identical_to_unstreamed(k, k_stream, tmp_cache):
    mesh = _mesh(2, 2)
    m, n = 13, 9
    a = _rand("dd", (m, k), seed=77)
    b = _rand("dd", (k, n), seed=78)
    plan = gemm.make_plan(m, k, n, backend="xla", mesh=mesh, k_panel=8,
                          use_cache=False)
    whole = gemm.execute(plan, a, b)
    _limbs_equal(gemm.execute(plan, a, b, k_stream=k_stream), whole)
    # the plan-field spelling streams identically to the per-call override
    planned = gemm.make_plan(m, k, n, backend="xla", mesh=mesh, k_panel=8,
                             k_stream=k_stream, use_cache=False)
    _limbs_equal(gemm.execute(planned, a, b), whole)


@pytest.mark.sharding
def test_k_stream_requires_mesh(tmp_cache):
    plan = gemm.make_plan(8, 8, 8, backend="xla", use_cache=False)
    a = _rand("dd", (8, 8), seed=79)
    with pytest.raises(ValueError, match="k_stream"):
        gemm.execute(plan, a, a, k_stream=4)
    with pytest.raises(ValueError, match="mesh"):
        gemm.make_plan(8, 8, 8, backend="xla", k_stream=4, use_cache=False)


def test_qd_tiles_tune_independently(tmp_cache):
    # same bucket, different limb count -> different cache rows
    kd = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas", nlimbs=2)
    kq = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas", nlimbs=4)
    assert kd != kq
    tmp_cache.put(kd, {"bm": 32, "bn": 64, "bk": 8})
    tmp_cache.put(kq, {"bm": 16, "bn": 32, "bk": 8})
    pd = gemm.make_plan(100, 100, 100, backend="pallas", platform="cpu")
    pq = gemm.make_plan(100, 100, 100, backend="pallas", platform="cpu",
                        precision="qd")
    assert (pd.bm, pd.bn, pd.bk) == (32, 64, 8) and pd.source == "tuned"
    assert (pq.bm, pq.bn, pq.bk) == (16, 32, 8) and pq.source == "tuned"
    assert pq.nlimbs == 4
