"""Property tests for double-word arithmetic against exact Fraction oracles."""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dd

# keep magnitudes in the normal range (XLA CPU flushes subnormals; see efts.py)
finite = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-1e100, max_value=1e100
).filter(lambda x: x == 0.0 or abs(x) > 1e-100)
small = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-1e8, max_value=1e8
).filter(lambda x: x == 0.0 or abs(x) > 1e-8)

DD_EPS64 = 2.0**-102  # contraction-robust two_prod: ~2^-104 + accumulation slack


def _dd_frac(x: dd.DD) -> Fraction:
    return Fraction(float(x.hi)) + Fraction(float(x.lo))


def _mk(a, b=0.0):
    return dd.from_hi_lo(jnp.float64(a), jnp.float64(b))


def _rel_err(got: Fraction, want: Fraction) -> float:
    if want == 0:
        return float(abs(got))
    return abs(float((got - want) / want))


@settings(max_examples=200, deadline=None)
@given(finite, small, finite, small)
def test_add_relative_error(a_hi, a_lo, b_hi, b_lo):
    a, b = _mk(a_hi, a_lo * 1e-20), _mk(b_hi, b_lo * 1e-20)
    got = _dd_frac(dd.add(a, b))
    want = _dd_frac(a) + _dd_frac(b)
    assert _rel_err(got, want) <= DD_EPS64


@settings(max_examples=200, deadline=None)
@given(finite, finite)
def test_mul_relative_error(a_hi, b_hi):
    a, b = _mk(a_hi), _mk(b_hi)
    got = _dd_frac(dd.mul(a, b))
    want = _dd_frac(a) * _dd_frac(b)
    assert _rel_err(got, want) <= DD_EPS64


@settings(max_examples=200, deadline=None)
@given(finite, finite)
def test_mul_of_singles_near_exact(a, b):
    # product of two 1-limb values: bounded by the two_prod error only
    got = _dd_frac(dd.mul(_mk(a), _mk(b)))
    want = Fraction(a) * Fraction(b)
    assert _rel_err(got, want) <= 2.0**-104


@settings(max_examples=100, deadline=None)
@given(small, small)
def test_div_roundtrip(a, b):
    if abs(b) < 1e-6:
        return
    q = dd.div(_mk(a), _mk(b))
    back = _dd_frac(dd.mul(q, _mk(b)))
    assert _rel_err(back, Fraction(a)) <= 8 * DD_EPS64 or abs(a) < 1e-280


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=1e-8, max_value=1e8))
def test_sqrt(a):
    r = dd.sqrt(_mk(a))
    back = _dd_frac(dd.mul(r, r))
    assert _rel_err(back, Fraction(a)) <= 16 * DD_EPS64


def test_sqrt_zero():
    r = dd.sqrt(_mk(0.0))
    assert float(r.hi) == 0.0 and float(r.lo) == 0.0


def test_canonical_form():
    # from_hi_lo renormalizes: |lo| <= ulp(hi)/2
    x = dd.from_hi_lo(jnp.float64(1.0), jnp.float64(1.0))
    assert float(x.hi) == 2.0 and float(x.lo) == 0.0


def test_sum_compensates():
    # summing n copies of (1 + eps_tiny) keeps the tiny part; plain f64 drops it
    n = 1024
    tiny = 1e-25
    arr = dd.DD(jnp.ones(n), jnp.full(n, tiny))
    s = dd.sum_(arr, axis=0)
    got = _dd_frac(s)
    want = Fraction(n) + Fraction(n) * Fraction(tiny)
    assert _rel_err(got, want) < 1e-30
    # f64 control: 1024 + 1024e-25 == 1024.0 exactly (the tiny part vanishes)
    assert float(jnp.sum(jnp.ones(n) + tiny)) == float(n)


def test_sum_odd_length_and_axes():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((7, 5))
    x = dd.from_float(jnp.asarray(a))
    s0 = dd.sum_(x, axis=0)
    np.testing.assert_allclose(np.asarray(dd.to_float(s0)), a.sum(0), rtol=1e-15)
    s1 = dd.sum_(x, axis=1)
    np.testing.assert_allclose(np.asarray(dd.to_float(s1)), a.sum(1), rtol=1e-15)
    sa = dd.sum_(x)
    np.testing.assert_allclose(float(dd.to_float(sa)), a.sum(), rtol=1e-15)


def test_dot_accuracy_vs_fraction():
    rng = np.random.default_rng(2)
    a = rng.standard_normal(257)
    b = rng.standard_normal(257)
    got = _dd_frac(dd.dot(dd.from_float(jnp.asarray(a)), dd.from_float(jnp.asarray(b))))
    want = sum((Fraction(x) * Fraction(y) for x, y in zip(a, b)), Fraction(0))
    assert _rel_err(got, want) < 1e-28


def test_comparisons_and_where():
    a = _mk(1.0, 1e-20)
    b = _mk(1.0, 2e-20)
    assert bool(dd.lt(a, b)) and bool(dd.le(a, b))
    assert bool(dd.gt(b, a)) and bool(dd.ge(b, a))
    w = dd.where(dd.lt(a, b), a, b)
    assert float(w.lo) == 1e-20


def test_f32_limbs():
    # df32: ~49-bit format out of f32 limbs (the TPU-VPU-native config)
    a = dd.from_float(jnp.float32(1.0))
    t = dd.add(a, dd.from_float(jnp.float32(2**-30)))
    # 1 + 2^-30 is not representable in f32 (24-bit) but is in df32
    assert float(t.hi) == 1.0 and float(t.lo) == 2.0**-30
    p = dd.mul(dd.from_float(jnp.float32(1.0 + 2**-12)), dd.from_float(jnp.float32(1.0 + 2**-12)))
    want = Fraction(1 + Fraction(1, 4096)) ** 2
    assert _rel_err(_dd_frac(p), want) < 2.0**-44
