"""Pallas DD-GEMM kernel vs pure-jnp oracle: shape/dtype/block sweeps.

Per the kernel contract, interpret mode executes the exact kernel body, so
these sweeps validate the TPU design's arithmetic on CPU.
"""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dd
from repro.kernels.ops import ddgemm, matmul_dd_xla
from repro.kernels.ref import ddgemm_ref


def _rand_dd(shape, dtype, rng, with_lo=True):
    hi = rng.standard_normal(shape)
    if dtype == jnp.float32:
        hi = hi.astype(np.float32)
    x = dd.from_float(jnp.asarray(hi, dtype=dtype))
    if with_lo:
        lo = rng.standard_normal(shape) * (1e-20 if dtype == jnp.float64 else 1e-9)
        x = dd.add(x, dd.from_float(jnp.asarray(lo, dtype=dtype)))
    return x


def _assert_dd_close(got: dd.DD, want: dd.DD, k: int, dtype):
    # DD values with equal *sums* may split (hi, lo) differently, so compare
    # the signed sum of component differences in f64 (exact for nearby limbs),
    # with tolerance k accumulations x DD unit roundoff on the result scale.
    u = dd.eps(dtype)
    scale = np.maximum(np.abs(np.asarray(want.hi, np.float64)), 1.0)
    err = np.abs(
        (np.asarray(got.hi, np.float64) - np.asarray(want.hi, np.float64))
        + (np.asarray(got.lo, np.float64) - np.asarray(want.lo, np.float64))
    )
    np.testing.assert_array_less(err, 16 * (k + 4) * u * scale + 1e-300)


SHAPES = [
    (8, 8, 8),
    (16, 32, 8),
    (32, 16, 64),
    (33, 17, 9),      # non-multiples -> padding path
    (1, 128, 1),      # degenerate tall-skinny
    (128, 8, 128),    # paper Fig. 4: small n
    (8, 128, 120),    # paper Fig. 6: small k
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_matches_oracle(m, k, n, dtype):
    rng = np.random.default_rng(hash((m, k, n, str(dtype))) % 2**32)
    a = _rand_dd((m, k), dtype, rng)
    b = _rand_dd((k, n), dtype, rng)
    got = ddgemm(a, b, bm=16, bn=16, bk=8)
    want = ddgemm_ref(a, b)
    _assert_dd_close(got, want, k, dtype)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 8, 16), (8, 32, 4), (64, 64, 32)])
def test_block_shape_sweep(bm, bn, bk):
    # the M_Tile analogue: results must be block-shape independent
    rng = np.random.default_rng(7)
    a = _rand_dd((64, 64), jnp.float64, rng)
    b = _rand_dd((64, 64), jnp.float64, rng)
    got = ddgemm(a, b, bm=bm, bn=bn, bk=bk)
    want = ddgemm_ref(a, b)
    _assert_dd_close(got, want, 64, jnp.float64)


def test_exactness_vs_fraction_small():
    # 4x4x4 against the exact rational product: error < 8 * 2^-104 * |C|
    rng = np.random.default_rng(3)
    a_np = rng.standard_normal((4, 4))
    b_np = rng.standard_normal((4, 4))
    got = ddgemm(dd.from_float(jnp.asarray(a_np)), dd.from_float(jnp.asarray(b_np)), bm=8, bn=8, bk=8)
    for i in range(4):
        for j in range(4):
            want = sum(
                (Fraction(a_np[i, p]) * Fraction(b_np[p, j]) for p in range(4)),
                Fraction(0),
            )
            got_f = Fraction(float(got.hi[i, j])) + Fraction(float(got.lo[i, j]))
            err = abs(float(got_f - want))
            assert err <= 8 * 2.0**-104 * max(1.0, abs(float(want)))


def test_e_l1_metric_matches_paper_band():
    # Paper Eq. 6 / §IV-B1: E_L1 between FPGA binary128 and reference is
    # ~1e-31..1e-30 for n < 512. dd64 (106-bit vs 113-bit) should land within
    # ~2 decades of that; what we actually check: E_L1 vs the oracle is tiny
    # and E_L1 vs plain f64 shows the precision gap.
    rng = np.random.default_rng(11)
    n = 64
    a_np, b_np = rng.random((n, n)), rng.random((n, n))
    a, b = dd.from_float(jnp.asarray(a_np)), dd.from_float(jnp.asarray(b_np))
    got = ddgemm(a, b, bm=32, bn=32, bk=16)
    want = ddgemm_ref(a, b)
    e_l1 = float(np.mean(np.abs(np.asarray(dd.to_float(dd.sub(got, want))))))
    assert e_l1 < 1e-28
    # the f64 'double' computation is ~1e-14 away -> DD genuinely adds bits
    e_f64 = float(np.mean(np.abs(a_np @ b_np - np.asarray(dd.to_float(got)))))
    assert 1e-17 < e_f64 < 1e-11


def test_deterministic():
    rng = np.random.default_rng(5)
    a = _rand_dd((32, 32), jnp.float64, rng)
    b = _rand_dd((32, 32), jnp.float64, rng)
    c1 = ddgemm(a, b, bm=16, bn=16, bk=8)
    c2 = ddgemm(a, b, bm=16, bn=16, bk=8)
    np.testing.assert_array_equal(np.asarray(c1.hi), np.asarray(c2.hi))
    np.testing.assert_array_equal(np.asarray(c1.lo), np.asarray(c2.lo))


def test_xla_backend_matches_oracle():
    rng = np.random.default_rng(9)
    a = _rand_dd((24, 40), jnp.float64, rng)
    b = _rand_dd((40, 24), jnp.float64, rng)
    got = matmul_dd_xla(a, b, chunk=16)
    want = ddgemm_ref(a, b)
    _assert_dd_close(got, want, 40, jnp.float64)


def test_zero_padding_is_exact():
    # padding must not perturb results: compare padded vs unpadded-size calls
    rng = np.random.default_rng(13)
    a = _rand_dd((30, 30), jnp.float64, rng)
    b = _rand_dd((30, 30), jnp.float64, rng)
    got = ddgemm(a, b, bm=16, bn=16, bk=16)  # pads to 32
    got2 = ddgemm(a, b, bm=8, bn=8, bk=8)    # pads to 32 differently... (30->32)
    want = ddgemm_ref(a, b)
    _assert_dd_close(got, want, 30, jnp.float64)
    _assert_dd_close(got2, want, 30, jnp.float64)
