"""Property tests for the error-free transformations.

two_sum is exact; two_prod is near-exact with the documented bound (the
contraction-robust variant trades bit-exactness for immunity to XLA:CPU's
fma contraction — see efts.py docstring).  Every bound is checked against
``fractions.Fraction`` oracles, both eagerly and under jit *in fused
broadcast contexts* (the exact setting where the naive Dekker formulation
was observed to collapse).
"""

from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import efts

# XLA CPU flushes subnormals to zero (FTZ), and EFT error terms of products of
# tiny normals are themselves subnormal — so EFT guarantees hold on the normal
# range only.  Constrain magnitudes well inside it (documented in efts.py).
finite64 = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-1e150, max_value=1e150
).filter(lambda x: x == 0.0 or abs(x) > 1e-120)
finite32 = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-2.0**50, max_value=2.0**50, width=32
).filter(lambda x: x == 0.0 or abs(x) > 1e-12)


def _frac(x) -> Fraction:
    return Fraction(float(x))


@settings(max_examples=200, deadline=None)
@given(finite64, finite64)
def test_two_sum_exact_f64(a, b):
    s, e = efts.two_sum(jnp.float64(a), jnp.float64(b))
    assert _frac(s) + _frac(e) == _frac(a) + _frac(b)
    assert float(s) == a + b  # s is the correctly rounded sum


@settings(max_examples=200, deadline=None)
@given(finite32, finite32)
def test_two_sum_exact_f32(a, b):
    a32, b32 = np.float32(a), np.float32(b)
    s, e = efts.two_sum(jnp.float32(a32), jnp.float32(b32))
    assert _frac(s) + _frac(e) == _frac(a32) + _frac(b32)


@settings(max_examples=200, deadline=None)
@given(finite64, finite64)
def test_two_prod_bound_f64(a, b):
    p, e = efts.two_prod(jnp.float64(a), jnp.float64(b))
    got = _frac(p) + _frac(e)
    want = _frac(a) * _frac(b)
    tol = efts.TWO_PROD_RELERR[jnp.dtype(jnp.float64)]
    assert abs(float(got - want)) <= tol * abs(float(want)) or want == 0
    # p is within 1 ulp of the rounded product
    assert abs(float(p) - a * b) <= abs(a * b) * 2.0**-52


@settings(max_examples=200, deadline=None)
@given(finite32, finite32)
def test_two_prod_bound_f32(a, b):
    a32, b32 = np.float32(a), np.float32(b)
    p, e = efts.two_prod(jnp.float32(a32), jnp.float32(b32))
    got = _frac(p) + _frac(e)
    want = _frac(a32) * _frac(b32)
    tol = efts.TWO_PROD_RELERR[jnp.dtype(jnp.float32)]
    assert abs(float(got - want)) <= tol * abs(float(want)) or want == 0


def test_two_prod_f32_is_exact():
    # with 12/12-bit splits all four partials are exact in f32, so the only
    # error source is the e1+(e2+e3) fold; on random data it is usually exact
    rng = np.random.default_rng(0)
    bad = 0
    for _ in range(200):
        a, b = np.float32(rng.standard_normal()), np.float32(rng.standard_normal())
        p, e = efts.two_prod(jnp.float32(a), jnp.float32(b))
        if _frac(p) + _frac(e) != _frac(a) * _frac(b):
            bad += 1
    assert bad <= 5  # rare e-fold rounding only


@settings(max_examples=100, deadline=None)
@given(finite64, finite64)
def test_quick_two_sum_exact_when_ordered(a, b):
    hi, lo = (a, b) if abs(a) >= abs(b) else (b, a)
    s, e = efts.quick_two_sum(jnp.float64(hi), jnp.float64(lo))
    assert _frac(s) + _frac(e) == _frac(hi) + _frac(lo)


@settings(max_examples=100, deadline=None)
@given(finite64)
def test_mask_split_exact(a):
    hi, lo = efts.mask_split(jnp.float64(a))
    assert _frac(hi) + _frac(lo) == _frac(a)
    # hi has at most 26 significant bits -> hi * hi is exact in f64
    assert _frac(float(hi) * float(hi)) == _frac(hi) * _frac(hi)


@settings(max_examples=100, deadline=None)
@given(finite32)
def test_mask_split_exact_f32(a):
    a32 = np.float32(a)
    hi, lo = efts.mask_split(jnp.float32(a32))
    assert _frac(hi) + _frac(lo) == _frac(a32)
    # 12-bit halves: all cross products exact in f32
    assert _frac(np.float32(float(hi)) * np.float32(float(lo))) == _frac(hi) * _frac(lo)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_two_prod_jit_fused_broadcast(dtype):
    """Regression: the setting where fma contraction broke Dekker two_prod.

    jit-compile a fused broadcast (8,1)x(1,8) two_prod and verify the bound
    elementwise against Fraction — this fails for the Veltkamp formulation.
    """
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((8, 1)), dtype)
    b = jnp.asarray(rng.standard_normal((1, 8)), dtype)
    p, e = jax.jit(efts.two_prod)(a, b)
    tol = efts.TWO_PROD_RELERR[jnp.dtype(dtype)]
    for i in range(8):
        for j in range(8):
            got = _frac(p[i, j]) + _frac(e[i, j])
            want = _frac(a[i, 0]) * _frac(b[0, j])
            assert abs(float(got - want)) <= tol * abs(float(want))


def test_two_sum_jit_fused_broadcast():
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((8, 1)))
    b = jnp.asarray(rng.standard_normal((1, 8)) * 1e-12)
    s, e = jax.jit(efts.two_sum)(a, b)
    for i in range(8):
        for j in range(8):
            assert _frac(s[i, j]) + _frac(e[i, j]) == _frac(a[i, 0]) + _frac(b[0, j])


@settings(max_examples=200, deadline=None)
@given(finite64, finite64)
def test_two_prod_exact_f64(a, b):
    p, e = efts.two_prod_exact(jnp.float64(a), jnp.float64(b))
    assert _frac(p) + _frac(e) == _frac(a) * _frac(b)


@settings(max_examples=200, deadline=None)
@given(finite64, finite64)
def test_two_prod_terms_sum_exactly(a, b):
    terms = efts.two_prod_terms(jnp.float64(a), jnp.float64(b))
    assert sum((_frac(t) for t in terms), Fraction(0)) == _frac(a) * _frac(b)


def test_two_sum_vectorized():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 3))
    b = rng.standard_normal((64, 3)) * 1e-12
    s, e = efts.two_sum(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(s) + np.asarray(e), a + b)
