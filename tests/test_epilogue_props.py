"""Rgemm epilogue semantics property suite (ISSUE 5 satellites).

Three guarantees, each regression-tested here:

  * **beta needs C** — ``execute(plan, a, b, beta=0.5)`` with ``c=None``
    used to silently drop beta (``_apply_epilogue`` only read it under
    ``if c is not None``); it now raises ``ValueError``, mirroring the
    alpha/c defaulting rules.  ``beta=0`` without C stays legal — that is
    the BLAS "C is not read" spelling every Rgemm caller uses.
  * **beta == 0 means C is NOT read** — a NaN/Inf C must not leak through
    ``0 * C``.  Covered for statically-zero betas (python float and tier
    scalar: the engine drops the C term before any arithmetic), and for
    *traced* zeros on both epilogue implementations: the tier post-step
    (``_apply_epilogue``'s where-guard) and the fused ozaki-pallas kernel
    drain.
  * **one epilogue, every path** — plain 2-D, vmap-batched, 1-axis
    sharded, and 2-D SUMMA-sharded execution apply the identical tier
    arithmetic: all four agree with the ``mp`` oracle cell-for-cell.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import mp
from repro.core.accuracy import max_rel_err as _rel_err
from repro.core.blas import rgemm
from repro.kernels.ref import ddgemm_ref, qdgemm_ref

ULP = {"dd": 2.0 ** -104, "qd": 2.0 ** -205}
REF = {"dd": ddgemm_ref, "qd": qdgemm_ref}


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(precision, shape, seed):
    rng = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)


def _poisoned(precision, shape, bad):
    """A C operand whose every entry is NaN or Inf (in the leading limb)."""
    hi = jnp.full(shape, jnp.nan if bad == "nan" else jnp.inf)
    limbs = [hi] + [jnp.zeros(shape)] * (mp.PRECISIONS[precision] - 1)
    return mp.from_limbs(limbs)


# --------------------------------------------------------------------------
# beta without C
# --------------------------------------------------------------------------


class TestBetaRequiresC:
    @pytest.mark.parametrize("beta", [0.5, -1.0])
    def test_nonzero_float_beta_without_c_raises(self, beta, tmp_cache):
        plan = gemm.make_plan(8, 8, 8, backend="xla")
        a, b = _rand("dd", (8, 8), 0), _rand("dd", (8, 8), 1)
        with pytest.raises(ValueError, match="beta"):
            gemm.execute(plan, a, b, beta=beta)

    def test_nonzero_tier_scalar_beta_without_c_raises(self, tmp_cache):
        plan = gemm.make_plan(8, 8, 8, backend="xla")
        a, b = _rand("dd", (8, 8), 0), _rand("dd", (8, 8), 1)
        with pytest.raises(ValueError, match="beta"):
            gemm.execute(plan, a, b,
                         beta=mp.from_float(jnp.asarray(0.25), "dd"))

    def test_rgemm_surface_raises_too(self, tmp_cache):
        a, b = _rand("dd", (8, 8), 0), _rand("dd", (8, 8), 1)
        with pytest.raises(ValueError, match="beta"):
            rgemm("n", "n", 1.0, a, b, 0.5, backend="xla")

    @pytest.mark.parametrize("beta", [0, 0.0])
    def test_beta_zero_without_c_is_the_blas_noop(self, beta, tmp_cache):
        # every BLAS caller writes rgemm(..., beta=0, C): "C is not read"
        plan = gemm.make_plan(8, 8, 8, backend="xla")
        a, b = _rand("dd", (8, 8), 0), _rand("dd", (8, 8), 1)
        got = gemm.execute(plan, a, b, beta=beta)
        assert _rel_err(got, ddgemm_ref(a, b)) < 16 * 8 * ULP["dd"]

    def test_tier_scalar_zero_beta_without_c_ok(self, tmp_cache):
        plan = gemm.make_plan(8, 8, 8, backend="xla")
        a, b = _rand("dd", (8, 8), 0), _rand("dd", (8, 8), 1)
        got = gemm.execute(plan, a, b, beta=mp.zeros((), "dd"))
        assert _rel_err(got, ddgemm_ref(a, b)) < 16 * 8 * ULP["dd"]


# --------------------------------------------------------------------------
# beta == 0 does not read C (NaN/Inf regression)
# --------------------------------------------------------------------------


class TestBetaZeroDoesNotReadC:
    @pytest.mark.parametrize("bad", ["nan", "inf"])
    @pytest.mark.parametrize("backend,precision", [
        ("xla", "dd"), ("xla", "qd"), ("ref", "dd"),
        ("pallas", "dd"), ("ozaki-pallas", "dd"), ("ozaki-pallas", "qd"),
    ])
    def test_static_zero_beta_guards_poisoned_c(self, backend, precision,
                                                bad, tmp_cache):
        m, k, n = 9, 11, 6
        a = _rand(precision, (m, k), 2)
        b = _rand(precision, (k, n), 3)
        c = _poisoned(precision, (m, n), bad)
        got = rgemm("n", "n", 1.0, a, b, 0.0, c, backend=backend)
        assert np.isfinite(np.asarray(mp.limbs(got)[0])).all()
        assert _rel_err(got, REF[precision](a, b)) < 16 * k * ULP[precision]

    def test_static_tier_scalar_zero_beta_guards(self, tmp_cache):
        a, b = _rand("dd", (8, 8), 4), _rand("dd", (8, 8), 5)
        c = _poisoned("dd", (8, 8), "nan")
        got = rgemm("n", "n", 1.0, a, b, mp.zeros((), "dd"), c,
                    backend="xla")
        assert np.isfinite(np.asarray(got.hi)).all()

    @pytest.mark.parametrize("backend", ["xla", "ozaki-pallas"])
    def test_traced_zero_beta_guards_poisoned_c(self, backend, tmp_cache):
        # beta only known zero at RUN time (a tracer): the post-step
        # where-guard and the fused kernel drain must both mask 0 * NaN.
        # Compared against the mp oracle AND the un-jitted plain product:
        # the engine pins padded operands behind an optimization_barrier,
        # so an outer jit over constant operands is bit-identical to the
        # eager call (the pre-existing ~1e-17 interpret-mode drift this
        # test used to paper over is fixed)
        m, k, n = 9, 11, 6
        plan = gemm.make_plan(m, k, n, backend=backend)
        a, b = _rand("dd", (m, k), 6), _rand("dd", (k, n), 7)
        c = _poisoned("dd", (m, n), "nan")

        @jax.jit
        def run(beta):
            return gemm.execute(plan, a, b, alpha=1.0, beta=beta, c=c)

        got = run(mp.from_float(jnp.asarray(0.0), "dd"))
        plain = gemm.execute(plan, a, b)  # eager, un-jitted
        assert np.isfinite(np.asarray(got.hi)).all()
        assert _rel_err(got, plain) < 4 * ULP["dd"]
        assert _rel_err(got, ddgemm_ref(a, b)) < 16 * k * ULP["dd"]
        # the jitted plain product matches the eager one bit-for-bit (the
        # constant-folding divergence this suite used to work around)
        jplain = jax.jit(lambda: gemm.execute(plan, a, b))()
        for le, lj in zip(mp.limbs(plain), mp.limbs(jplain)):
            np.testing.assert_array_equal(np.asarray(le), np.asarray(lj))
        # ...and a traced NONZERO beta still reads C normally
        clean = _rand("dd", (m, n), 8)

        @jax.jit
        def run2(beta):
            return gemm.execute(plan, a, b, alpha=1.0, beta=beta, c=clean)

        bval = mp.from_float(jnp.asarray(-0.5), "dd")
        got = run2(bval)
        want = mp.add(plain,
                      mp.mul(mp.broadcast_to(bval, clean.shape), clean))
        assert _rel_err(got, want) < 16 * k * ULP["dd"]

    def test_batched_beta_zero_guard(self, tmp_cache):
        a = _rand("dd", (3, 8, 8), 9)
        b = _rand("dd", (8, 8), 10)
        c = _poisoned("dd", (8, 8), "nan")
        got = rgemm("n", "n", 1.0, a, b, 0.0, c, backend="xla")
        assert got.shape == (3, 8, 8)
        assert np.isfinite(np.asarray(got.hi)).all()


# --------------------------------------------------------------------------
# epilogue agreement: plain / batched / 1-axis sharded / 2-D SUMMA
# --------------------------------------------------------------------------


class TestEpiloguePathAgreement:
    @pytest.mark.parametrize("precision", ["dd", "qd"])
    @pytest.mark.parametrize("mode", ["plain", "batched", "sharded",
                                      "summa2d"])
    def test_modes_agree_with_mp_oracle(self, mode, precision, tmp_cache):
        from jax.sharding import Mesh

        m, k, n = 9, 21, 6  # odd everything: padding + K-panel remainder
        a = _rand(precision, (m, k), 11)
        b = _rand(precision, (k, n), 12)
        c = _rand(precision, (m, n), 13)
        one = mp.from_float(jnp.asarray(1.0), precision)
        third = mp.div(one, mp.from_float(jnp.asarray(3.0), precision))
        m7th = mp.div(mp.neg(one), mp.from_float(jnp.asarray(7.0),
                                                 precision))
        kwargs = dict(backend="xla")
        if mode == "sharded":
            kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("rows",))
        elif mode == "summa2d":
            kwargs["mesh"] = Mesh(
                np.array(jax.devices()[:1]).reshape(1, 1), ("rows", "cols"))
            kwargs["k_panel"] = 8  # forces a multi-step SUMMA loop
        if mode == "batched":
            a = mp.map_limbs(lambda l: jnp.stack([l, l * 2.0]), a)
        got = rgemm("n", "n", third, a, b, m7th, c, **kwargs)
        prod = REF[precision](a[0] if mode == "batched" else a, b)
        want = mp.add(mp.mul(mp.broadcast_to(third, prod.shape), prod),
                      mp.mul(mp.broadcast_to(m7th, c.shape), c))
        gate = 16 * k * ULP[precision]
        if mode == "batched":
            assert _rel_err(got[0], want) < gate
            # 2x scaling is exact: the second element's oracle scales too
            want2 = mp.add(
                mp.mul(mp.broadcast_to(third, prod.shape),
                       mp.mul_float(prod, jnp.float64(2.0))),
                mp.mul(mp.broadcast_to(m7th, c.shape), c))
            assert _rel_err(got[1], want2) < gate
        else:
            assert _rel_err(got, want) < gate
