"""Unified GEMM engine: plan/autotune/dispatch, batched + sharded paths.

Covers the ISSUE-1 acceptance surface: all four backends route through
GemmPlan/execute; batched results match a looped ref oracle to DD
tolerance; sharded row-partitioned execution matches the oracle (including
on a real multi-device mesh, via a subprocess with forced host devices);
tuned block shapes round-trip through the on-disk cache and are reused by
the planner.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import dd
from repro.core.blas import rgemm
from repro.kernels.ref import ddgemm_ref

DD_TOL = 2.0 ** -104


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand_dd(shape, seed):
    rng = np.random.default_rng(seed)
    return dd.from_float(jnp.asarray(rng.standard_normal(shape)))


def _dd_err(got: dd.DD, want: dd.DD) -> float:
    return float(np.abs(
        (np.asarray(got.hi, np.float64) - np.asarray(want.hi, np.float64))
        + (np.asarray(got.lo, np.float64) - np.asarray(want.lo, np.float64))
    ).max())


# --------------------------------------------------------------------------
# planning
# --------------------------------------------------------------------------


class TestPlan:
    def test_all_backends_route_through_plan(self, tmp_cache):
        a, b = _rand_dd((20, 12), 0), _rand_dd((12, 24), 1)
        want = ddgemm_ref(a, b)
        for be in ("pallas", "ozaki", "xla", "ref"):
            plan = gemm.make_plan(20, 12, 24, backend=be)
            assert plan.backend == be
            got = gemm.execute(plan, a, b)
            assert _dd_err(got, want) < 16 * 16 * DD_TOL * 10

    def test_backend_env_resolution(self, monkeypatch):
        monkeypatch.setenv("REPRO_GEMM_BACKEND", "xla")
        assert gemm.make_plan(8, 8, 8).backend == "xla"
        monkeypatch.delenv("REPRO_GEMM_BACKEND")
        assert gemm.make_plan(8, 8, 8).backend == "ozaki"
        with pytest.raises(ValueError):
            gemm.make_plan(8, 8, 8, backend="systolic9000")

    def test_blocks_clamped_to_problem(self, tmp_cache):
        plan = gemm.make_plan(10, 6, 20, backend="pallas")
        assert (plan.bm, plan.bn, plan.bk) == (16, 24, 8)

    def test_plan_and_overrides_are_exclusive(self, tmp_cache):
        plan = gemm.make_plan(8, 8, 8, backend="ref")
        a, b = _rand_dd((8, 8), 40), _rand_dd((8, 8), 41)
        with pytest.raises(ValueError, match="not both"):
            gemm.matmul(a, b, plan=plan, backend="ozaki")

    def test_unbatched_plan_rejects_batched_operands(self, tmp_cache):
        plan = gemm.make_plan(8, 8, 8, backend="ref")
        a, b = _rand_dd((3, 8, 8), 42), _rand_dd((8, 8), 43)
        with pytest.raises(ValueError, match="batch"):
            gemm.execute(plan, a, b)

    def test_plan_is_reusable_and_frozen(self, tmp_cache):
        plan = gemm.make_plan(16, 16, 16, backend="xla")
        a, b = _rand_dd((16, 16), 2), _rand_dd((16, 16), 3)
        c1, c2 = gemm.execute(plan, a, b), gemm.execute(plan, a, b)
        np.testing.assert_array_equal(np.asarray(c1.hi), np.asarray(c2.hi))
        with pytest.raises(Exception):
            plan.backend = "ref"


# --------------------------------------------------------------------------
# batched GEMM vs looped ref oracle
# --------------------------------------------------------------------------


class TestBatched:
    @pytest.mark.parametrize("backend", ["pallas", "ozaki", "xla", "ref"])
    def test_batched_a_matches_looped_oracle(self, backend, tmp_cache):
        a, b = _rand_dd((5, 14, 10), 4), _rand_dd((10, 12), 5)
        got = gemm.matmul(a, b, backend=backend)
        assert got.shape == (5, 14, 12)
        for i in range(5):
            want = ddgemm_ref(a[i], b)
            scale = max(1.0, float(np.abs(np.asarray(want.hi)).max()))
            assert _dd_err(got[i], want) < 16 * 14 * DD_TOL * scale

    def test_batched_both_and_broadcast(self, tmp_cache):
        a = _rand_dd((2, 3, 9, 7), 6)
        b = _rand_dd((3, 7, 11), 7)  # broadcasts over the leading 2
        got = gemm.matmul(a, b, backend="xla")
        assert got.shape == (2, 3, 9, 11)
        for i in range(2):
            for j in range(3):
                want = ddgemm_ref(a[i, j], b[j])
                assert _dd_err(got[i, j], want) < 16 * 7 * DD_TOL * 4

    def test_batched_b_only(self, tmp_cache):
        a = _rand_dd((6, 8), 8)
        b = _rand_dd((4, 8, 6), 9)
        got = gemm.matmul(a, b, backend="ozaki")
        for i in range(4):
            want = ddgemm_ref(a, b[i])
            assert _dd_err(got[i], want) < 16 * 8 * DD_TOL * 4


# --------------------------------------------------------------------------
# sharded GEMM
# --------------------------------------------------------------------------


_SHARD_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec
from repro import gemm
from repro.core import dd
from repro.kernels.ref import ddgemm_ref

assert len(jax.devices()) == 2, jax.devices()
mesh = Mesh(np.array(jax.devices()), ("x",))
rng = np.random.default_rng(0)
a = dd.from_float(jnp.asarray(rng.standard_normal((30, 16))))
b = dd.from_float(jnp.asarray(rng.standard_normal((16, 12))))
want = ddgemm_ref(a, b)
for be in ("pallas", "ozaki-pallas", "xla"):
    got = gemm.matmul(a, b, backend=be, mesh=mesh)
    err = np.abs((np.asarray(got.hi) - np.asarray(want.hi))
                 + (np.asarray(got.lo) - np.asarray(want.lo))).max()
    assert err < 1e-28, (be, err)
# even-multiple M keeps the all-gather-free row-sharded output layout
a32 = dd.from_float(jnp.asarray(rng.standard_normal((32, 16))))
got = gemm.matmul(a32, b, backend="xla", mesh=mesh)
assert got.hi.sharding.spec == PartitionSpec("x"), got.hi.sharding
print("SHARDED_OK")
"""


_SUMMA_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec
from repro import gemm
from repro.core import mp
from repro.kernels.ref import ddgemm_ref, qdgemm_ref

assert len(jax.devices()) == 4, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("rows", "cols"))
ULP = {"dd": 2.0 ** -104, "qd": 2.0 ** -205}

def rnd(prec, s, seed):
    r = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(r.standard_normal(s)), prec)

def err(g, w):
    return float(max(np.abs(np.asarray(gl, np.float64)
                            - np.asarray(wl, np.float64)).max()
                     for gl, wl in zip(mp.limbs(g), mp.limbs(w))))

a, b = rnd("dd", (30, 40), 1), rnd("dd", (40, 12), 2)
want = ddgemm_ref(a, b)
gate = 16 * 40 * ULP["dd"] * 8
for be in ("xla", "ozaki-pallas"):
    assert err(gemm.matmul(a, b, backend=be, mesh=mesh), want) < gate, be
# qd tier on the same 2-D mesh
aq, bq = rnd("qd", (16, 24), 3), rnd("qd", (24, 8), 4)
assert err(gemm.matmul(aq, bq, backend="xla", mesh=mesh),
           qdgemm_ref(aq, bq)) < 16 * 24 * ULP["qd"] * 8
# even-multiple shapes keep the all-gather-free 2-D block-sharded layout
a32, b12 = rnd("dd", (32, 40), 5), rnd("dd", (40, 12), 6)
got = gemm.matmul(a32, b12, backend="xla", mesh=mesh)
assert got.hi.sharding.spec == PartitionSpec("rows", "cols"), \
    got.hi.sharding
# acceptance cell: batched + 2-D-sharded dd + full epilogue, ONE call
ab, c = rnd("dd", (3, 30, 40), 7), rnd("dd", (30, 12), 8)
got = gemm.matmul(ab, b, backend="xla", mesh=mesh,
                  alpha=2.0, beta=-0.5, c=c)
two = mp.from_float(jnp.asarray(2.0))
mhalf = mp.from_float(jnp.asarray(-0.5))
for i in range(3):
    w = ddgemm_ref(ab[i], b)
    w = mp.add(mp.mul(mp.broadcast_to(two, w.shape), w),
               mp.mul(mp.broadcast_to(mhalf, c.shape), c))
    assert err(got[i], w) < gate, i
# degenerate topologies through the same loop
for shape in ((1, 4), (4, 1)):
    m2 = Mesh(np.array(jax.devices()).reshape(shape), ("rows", "cols"))
    assert err(gemm.matmul(a, b, backend="xla", mesh=m2), want) < gate, shape
# production LM mesh names resolve through the gemm rule table
m3 = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
p3 = gemm.make_plan(30, 40, 12, backend="xla", mesh=m3)
assert (p3.shard_axis, p3.shard_axis_n) == ("data", "model")
assert err(gemm.execute(p3, a, b), want) < gate
print("SUMMA_OK")
"""


class TestSharded:
    def test_sharded_single_device_mesh(self, tmp_cache):
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("rows",))
        a, b = _rand_dd((26, 10), 10), _rand_dd((10, 18), 11)
        want = ddgemm_ref(a, b)
        got = gemm.matmul(a, b, backend="xla", mesh=mesh)
        assert _dd_err(got, want) < 16 * 10 * DD_TOL * 4
        plan = gemm.make_plan(26, 10, 18, backend="xla", mesh=mesh)
        assert plan.shard_axis == "rows"

    def test_batched_plus_sharded_in_one_call(self, tmp_cache):
        # the old NotImplementedError path: vmap now composes outside the
        # SUMMA shard_map, so batched + sharded is ONE engine call
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        plan = gemm.make_plan(8, 8, 8, backend="xla", mesh=mesh,
                              batch_shape=(2,))
        a, b = _rand_dd((2, 8, 8), 12), _rand_dd((8, 8), 13)
        got = gemm.execute(plan, a, b)
        assert got.shape == (2, 8, 8)
        for i in range(2):
            assert _dd_err(got[i], ddgemm_ref(a[i], b)) < 16 * 8 * DD_TOL * 4

    def test_column_only_sharding_runs_sharded(self, tmp_cache):
        # an explicit shard_axis_n= claiming a 1-axis mesh is pure column
        # sharding (shard_axis stays None) — it must run the SUMMA loop,
        # not silently fall through to the unsharded path
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
        plan = gemm.make_plan(16, 10, 24, backend="xla", mesh=mesh,
                              shard_axis_n="x")
        assert (plan.shard_axis, plan.shard_axis_n) == (None, "x")
        a, b = _rand_dd((16, 10), 16), _rand_dd((10, 24), 17)
        got = gemm.execute(plan, a, b)
        assert _dd_err(got, ddgemm_ref(a, b)) < 16 * 10 * DD_TOL * 4
        # (the column-sharded output layout is asserted on a real
        # multi-device mesh in _SUMMA_SCRIPT — a size-1 axis normalizes
        # to the replicated spec, so it is unobservable here)

    def test_summa_2d_mesh_single_device(self, tmp_cache):
        # a 2-axis (1, 1) mesh drives the full SUMMA loop (both mesh axes,
        # K-panel streaming) on one device — the always-on conformance cell
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("rows", "cols"))
        plan = gemm.make_plan(26, 40, 18, backend="xla", mesh=mesh,
                              k_panel=8)
        assert (plan.shard_axis, plan.shard_axis_n) == ("rows", "cols")
        a, b = _rand_dd((26, 40), 14), _rand_dd((40, 18), 15)
        got = gemm.execute(plan, a, b)
        assert _dd_err(got, ddgemm_ref(a, b)) < 16 * 40 * DD_TOL * 4

    @pytest.mark.slow
    def test_sharded_two_forced_host_devices(self):
        out = _run_forced_devices(_SHARD_SCRIPT, 2)
        assert "SHARDED_OK" in out

    @pytest.mark.slow
    @pytest.mark.sharding
    def test_summa_four_forced_host_devices(self):
        # the ISSUE-5 acceptance cell: batched + 2-D-sharded dd GEMM in ONE
        # engine.execute call on a real 2x2 host-device mesh, vs the mp
        # oracle at the tier accuracy gates
        out = _run_forced_devices(_SUMMA_SCRIPT, 4)
        assert "SUMMA_OK" in out


def _run_forced_devices(script: str, ndev: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", script],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


# --------------------------------------------------------------------------
# rgemm epilogue through the engine (nonsquare + transposed + DD scalars)
# --------------------------------------------------------------------------


class TestRgemmEpilogue:
    def test_nonsquare_transposed_dd_alpha_beta(self, tmp_cache):
        rng = np.random.default_rng(21)
        a_np = rng.standard_normal((9, 17))   # op(A) = A^T: (17, 9)
        b_np = rng.standard_normal((9, 13))   # op(B) = B:   (9, 13)
        c_np = rng.standard_normal((17, 13))
        third = dd.div(dd.from_float(jnp.asarray(1.0)),
                       dd.from_float(jnp.asarray(3.0)))     # 1/3, not f64
        seventh = dd.div(dd.from_float(jnp.asarray(-1.0)),
                         dd.from_float(jnp.asarray(7.0)))   # -1/7
        a, b = dd.from_float(jnp.asarray(a_np)), dd.from_float(jnp.asarray(b_np))
        c = dd.from_float(jnp.asarray(c_np))
        got = rgemm("t", "n", third, a, b, seventh, c, backend="xla")
        # DD oracle with the same DD epilogue
        prod = ddgemm_ref(dd.DD(a.hi.T, a.lo.T), b)
        want = dd.add(
            dd.mul(dd.DD(jnp.broadcast_to(third.hi, prod.shape),
                         jnp.broadcast_to(third.lo, prod.shape)), prod),
            dd.mul(dd.DD(jnp.broadcast_to(seventh.hi, c.shape),
                         jnp.broadcast_to(seventh.lo, c.shape)), c))
        assert _dd_err(got, want) < 1e-28
        # f64 sanity
        want_f64 = a_np.T @ b_np / 3.0 - c_np / 7.0
        assert np.abs(np.asarray(dd.to_float(got)) - want_f64).max() < 1e-13

    def test_batched_transpose_flag(self, tmp_cache):
        # 't' on a batched operand must swap only the matrix axes
        a = _rand_dd((4, 8, 6), 24)   # op(A): batch of (6, 8)
        b = _rand_dd((8, 5), 25)
        got = rgemm("t", "n", 1.0, a, b, 0.0, backend="xla")
        assert got.shape == (4, 6, 5)
        for i in range(4):
            want = ddgemm_ref(dd.DD(a.hi[i].T, a.lo[i].T), b)
            assert _dd_err(got[i], want) < 16 * 8 * DD_TOL * 4

    def test_rgemm_with_prebuilt_plan(self, tmp_cache):
        a, b = _rand_dd((12, 20), 22), _rand_dd((20, 8), 23)
        plan = gemm.make_plan(12, 20, 8, backend="pallas", bm=8, bn=8, bk=8)
        got = rgemm("n", "n", 1.0, a, b, 0.0, plan=plan)
        assert _dd_err(got, ddgemm_ref(a, b)) < 16 * 20 * DD_TOL * 4


# --------------------------------------------------------------------------
# autotune + plan cache round-trip
# --------------------------------------------------------------------------


class TestAutotuneCache:
    def test_cache_round_trip_on_disk(self, tmp_cache):
        key = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas")
        tmp_cache.put(key, {"bm": 32, "bn": 64, "bk": 8})
        # fresh object, same path -> reads from disk, not memory
        reread = gemm.PlanCache(tmp_cache.path)
        assert reread.get(key) == {"bm": 32, "bn": 64, "bk": 8}
        with open(tmp_cache.path) as f:
            assert key in json.load(f)

    def test_planner_uses_tuned_blocks_in_bucket(self, tmp_cache):
        key = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas")
        tmp_cache.put(key, {"bm": 32, "bn": 64, "bk": 8})
        # 100 and 120 share the 128-bucket -> both pick the tuned entry
        for mkn in (100, 120):
            plan = gemm.make_plan(mkn, mkn, mkn, backend="pallas",
                                  platform="cpu")
            assert plan.source == "tuned"
            assert (plan.bm, plan.bn, plan.bk) == (32, 64, 8)
        # explicit override beats the cache
        plan = gemm.make_plan(100, 100, 100, backend="pallas",
                              platform="cpu", bm=16)
        assert plan.source == "override" and plan.bm == 16
        # different bucket -> heuristic
        plan = gemm.make_plan(16, 16, 16, backend="pallas", platform="cpu")
        assert plan.source == "heuristic"

    def test_malformed_cache_entry_degrades_to_heuristic(self, tmp_cache):
        key = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas")
        tmp_cache.put(key, {"bm": 0, "bn": "lots", "bk": 8})
        plan = gemm.make_plan(100, 100, 100, backend="pallas",
                              platform="cpu")
        assert plan.source == "heuristic" and plan.bm > 0

    @pytest.mark.parametrize("garbage", [
        b'{"cpu/float64/128x128x128/pallas": {"bm": 32',  # truncated write
        b"\x00\x80 not json at all \xff",                 # binary noise
        b"[1, 2, 3]",                                     # valid JSON, wrong shape
    ])
    def test_corrupt_cache_file_warns_and_retunes(self, tmp_path, garbage):
        # a torn/garbled on-disk cache must cost a warning and a heuristic
        # plan, never an exception in every GEMM that consults the bucket
        path = tmp_path / "corrupt.json"
        path.write_bytes(garbage)
        cache = gemm.PlanCache(str(path))
        gemm.set_default_cache(cache)
        try:
            with pytest.warns(RuntimeWarning, match="cache"):
                plan = gemm.make_plan(100, 100, 100, backend="pallas",
                                      platform="cpu")
            assert plan.source == "heuristic" and plan.bm > 0
            # the poisoned file is recoverable: a put() rewrites it cleanly
            key = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas")
            cache.put(key, {"bm": 32, "bn": 64, "bk": 8})
            assert gemm.PlanCache(str(path)).get(key) == \
                {"bm": 32, "bn": 64, "bk": 8}
            replan = gemm.make_plan(100, 100, 100, backend="pallas",
                                    platform="cpu")
            assert replan.source == "tuned" and replan.bm == 32
        finally:
            gemm.set_default_cache(None)

    def test_autotune_persists_winner(self, tmp_cache, monkeypatch):
        # tuned under backend="auto": the entry must land under the RESOLVED
        # backend key, where make_plan will actually look it up
        monkeypatch.setenv("REPRO_GEMM_BACKEND", "pallas")
        cands = [{"bm": 16, "bn": 16, "bk": 8}, {"bm": 32, "bn": 32, "bk": 16}]
        plan = gemm.autotune(32, 32, 32, backend="auto",
                             candidates=cands, iters=1)
        assert plan.source == "tuned"
        assert {"bm": plan.bm, "bn": plan.bn, "bk": plan.bk} in cands
        replanned = gemm.make_plan(32, 32, 32, backend="pallas")
        assert replanned.source == "tuned"
        assert (replanned.bm, replanned.bn, replanned.bk) == \
            (plan.bm, plan.bn, plan.bk)

    def test_candidate_blocks_respect_vmem(self):
        for blk in gemm.candidate_blocks(4096, 4096, 4096):
            assert gemm.vmem_bytes(**blk) < 16 * 2**20

    def test_shape_bucket(self):
        assert gemm.shape_bucket(100, 100, 100) == "128x128x128"
        assert gemm.shape_bucket(128, 16, 1) == "128x16x8"

    def test_batched_plans_tune_apart_from_2d_bucket(self, tmp_cache):
        # since schema v3 the batch factor folds into the key — a
        # vmap-batched plan must NOT adopt tiles tuned for the 2-D bucket
        # (its VMEM pressure differs by the batch factor)
        k2d = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas")
        assert k2d.startswith(f"v{gemm.cache.SCHEMA}/")
        tmp_cache.put(k2d, {"bm": 32, "bn": 64, "bk": 8})
        plan = gemm.make_plan(100, 100, 100, backend="pallas",
                              platform="cpu", batch_shape=(5,))
        assert plan.source == "heuristic"  # 2-D entry not reused
        kb = gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas",
                            batch_shape=(5,))
        assert kb != k2d
        tmp_cache.put(kb, {"bm": 16, "bn": 32, "bk": 8})
        plan = gemm.make_plan(100, 100, 100, backend="pallas",
                              platform="cpu", batch_shape=(5,))
        assert plan.source == "tuned"
        assert (plan.bm, plan.bn, plan.bk) == (16, 32, 8)
        # batch shapes bucket by flattened power-of-two size
        assert gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas",
                              batch_shape=(2, 3)) == \
            gemm.cache_key("cpu", "float64", 100, 100, 100, "pallas",
                           batch_shape=(8,))

    def test_schema_v4_orphans_v3_rows_and_stale_quarantine(self, tmp_path):
        # schema v4 spells the limb count in the dtype segment for every
        # tier (``float64x2``, not bare ``float64`` for dd).  A cache file
        # written under v3 must degrade to heuristics (warn-free orphaning
        # — the rows are simply never consulted), re-tune into v4 keys,
        # and its stale/malformed quarantine rows must answer None rather
        # than crash plan-time quarantine checks.
        path = tmp_path / "plans.json"
        v3_rows = {
            # the old dd spelling (no limb-count suffix) and an old qd row
            "v3/cpu/float64/b1/128x128x128/pallas": {"bm": 64, "bn": 64,
                                                     "bk": 16},
            "v3/cpu/float64x4/b1/128x128x128/pallas": {"bm": 8, "bn": 8,
                                                       "bk": 8},
            # quarantine rows survive schema bumps (namespaced apart) but
            # malformed timestamps must read as expired, not raise
            "quarantine/v1/cpu/ozaki-pallas/x2": {"reason": "old",
                                                  "unix_time": "not-a-time"},
            "quarantine/v1/cpu/pallas/x3": {"reason": "no ts"},
        }
        path.write_text(json.dumps(v3_rows))
        cache = gemm.PlanCache(str(path))
        gemm.set_default_cache(cache)
        try:
            # v3 tuned rows are orphaned: both tiers fall back to heuristics
            for prec, v3_bm in (("dd", 64), ("qd", 8)):
                plan = gemm.make_plan(100, 100, 100, backend="pallas",
                                      platform="cpu", precision=prec)
                assert plan.source == "heuristic"
                assert plan.bm != v3_bm or plan.source == "heuristic"
            # stale quarantine rows: malformed timestamps answer None
            assert gemm.quarantined("cpu", "ozaki-pallas", 2) is None
            assert gemm.quarantined("cpu", "pallas", 3) is None
            # re-tuning writes v4 keys alongside the orphaned v3 rows
            for prec, nl in (("dd", 2), ("td", 3), ("qd", 4)):
                key = gemm.cache_key("cpu", "float64", 100, 100, 100,
                                     "pallas", nlimbs=nl)
                assert key.startswith("v4/") and f"float64x{nl}" in key
                cache.put(key, {"bm": 16, "bn": 32, "bk": 8})
                plan = gemm.make_plan(100, 100, 100, backend="pallas",
                                      platform="cpu", precision=prec)
                assert plan.source == "tuned"
                assert (plan.bm, plan.bn, plan.bk) == (16, 32, 8)
            # the orphaned rows are untouched on disk (no destructive
            # migration), and the v4 rows coexist with them
            on_disk = json.loads(path.read_text())
            assert all(k in on_disk for k in v3_rows)
        finally:
            gemm.set_default_cache(None)

    def test_autotune_populates_batched_bucket(self, tmp_cache):
        # autotune(batch_shape=) is the API that fills batched buckets:
        # the winner persists under the batched key, the 2-D bucket stays
        # untouched
        cands = [{"bm": 16, "bn": 16, "bk": 8}, {"bm": 8, "bn": 8, "bk": 8}]
        plan = gemm.autotune(16, 16, 16, backend="xla", batch_shape=(4,),
                             candidates=cands, iters=1)
        assert plan.batch == "vmap"
        replan = gemm.make_plan(16, 16, 16, backend="xla",
                                batch_shape=(4,))
        assert replan.source == "tuned"
        assert (replan.bm, replan.bn, replan.bk) == \
            (plan.bm, plan.bn, plan.bk)
        assert gemm.make_plan(16, 16, 16, backend="xla").source == \
            "heuristic"

    def test_explicit_cache_beats_env_var(self, tmp_cache, tmp_path,
                                          monkeypatch):
        # a cache installed via set_default_cache must win over
        # $REPRO_GEMM_CACHE pointing elsewhere
        monkeypatch.setenv("REPRO_GEMM_CACHE", str(tmp_path / "other.json"))
        assert gemm.default_cache() is tmp_cache


class TestCompatShim:
    def test_backend_kwargs_forwarded(self):
        # the legacy core.gemm.matmul surface still threads backend-specific
        # kwargs (ozaki slicing knobs, xla chunk) through the planner
        from repro.core.gemm import matmul as shim_matmul

        a, b = _rand_dd((10, 8), 30), _rand_dd((8, 12), 31)
        want = ddgemm_ref(a, b)
        got = shim_matmul(a, b, backend="ozaki", full=True, target_bits=107)
        assert _dd_err(got, want) < 16 * 8 * DD_TOL * 4
        got = shim_matmul(a, b, backend="xla", chunk=4)
        assert _dd_err(got, want) < 16 * 8 * DD_TOL * 4
