"""Guarded execution: the ``check=`` ladder's hazard-propagation contract.

The documented policy, asserted cell by cell: ``check="none"`` propagates
non-finite values IEEE-style (the kernel contract), ``check="finite"``
raises a typed :class:`NumericalHazardError` naming the offending operand
and first bad index, and — for the sliced backends — flags
slice-extraction anchor overflow (:class:`SliceOverflowError`) that would
otherwise corrupt slices silently.  The matrix runs NaN and Inf through
each of A, B, and C across every backend x {dd, qd} cell.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import mp
from repro.kernels.ref import ddgemm_ref
from repro.runtime.faults import NumericalHazardError, SliceOverflowError

# qd has no whole-K 'ozaki' tier (slice count explodes past the 212-bit
# target); pallas cells are covered by the dd column — interpret-mode qd
# compiles add minutes without adding policy coverage
BACKENDS = {
    "dd": ("xla", "ref", "ozaki", "ozaki-pallas"),
    "qd": ("xla", "ref"),
}
CELLS = [(p, be) for p, bes in BACKENDS.items() for be in bes]

N = 8
BAD_IDX = (2, 3)

# backends whose Ozaki slice extraction SWALLOWS a NaN operand entry into
# finite-but-wrong output (the anchor sum (NaN + sigma) - sigma is masked
# by the extraction's zero-handling): the silent-corruption case
# check="finite" exists to catch.  Inf still propagates there.
SLICED = ("ozaki", "ozaki-pallas")


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(precision, shape, seed):
    rng = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)


def _poison(x, index, value):
    """Set limb 0 of one entry to ``value`` (NaN/Inf)."""
    ls = list(mp.limbs(x))
    l0 = np.asarray(ls[0]).copy()
    l0[index] = value
    ls[0] = jnp.asarray(l0)
    return mp.from_limbs(ls)


def _any_nonfinite(x) -> bool:
    return any(bool(jnp.any(~jnp.isfinite(l))) for l in mp.limbs(x))


def _hazard_args(precision, operand, hazard):
    """(a, b, epilogue-kwargs) with ``hazard`` poisoned into ``operand``."""
    a = _rand(precision, (N, N), 0)
    b = _rand(precision, (N, N), 1)
    c = _rand(precision, (N, N), 2)
    val = np.nan if hazard == "nan" else np.inf
    if operand == "A":
        a = _poison(a, BAD_IDX, val)
    elif operand == "B":
        b = _poison(b, BAD_IDX, val)
    else:
        c = _poison(c, BAD_IDX, val)
    kw = {"alpha": 1.0, "beta": 1.0, "c": c} if operand == "C" else {}
    return a, b, kw


@pytest.mark.parametrize("hazard", ["nan", "inf"])
@pytest.mark.parametrize("operand", ["A", "B", "C"])
@pytest.mark.parametrize("precision,backend", CELLS)
class TestHazardMatrix:
    def test_check_none_propagates(self, tmp_cache, precision, backend,
                                   operand, hazard):
        a, b, kw = _hazard_args(precision, operand, hazard)
        out = gemm.matmul(a, b, backend=backend, check="none", **kw)
        if backend in SLICED and operand in ("A", "B") and hazard == "nan":
            # slice extraction swallows the NaN: the result is FINITE and
            # WRONG — undetectable without check="finite".  Assert both
            # halves so a future extraction change that restores honest
            # propagation shows up here.
            assert not _any_nonfinite(out)
            clean = ddgemm_ref(_rand("dd", (N, N), 0), _rand("dd", (N, N), 1))
            dev = np.abs(np.asarray(mp.to_float(out))
                         - np.asarray(mp.to_float(clean))).max()
            assert dev > 0.1, "NaN poison left no trace at all"
        else:
            assert _any_nonfinite(out), \
                f"{hazard} in {operand} vanished on {backend}/{precision}"

    def test_check_finite_raises_naming_operand(self, tmp_cache, precision,
                                                backend, operand, hazard):
        a, b, kw = _hazard_args(precision, operand, hazard)
        with pytest.raises(NumericalHazardError) as ei:
            gemm.matmul(a, b, backend=backend, check="finite", **kw)
        err = ei.value
        assert err.operand == operand
        assert err.kind == hazard
        assert err.backend == backend
        assert err.precision == precision
        assert err.index == BAD_IDX
        assert (err.nan_count, err.inf_count) == \
            ((1, 0) if hazard == "nan" else (0, 1))
        # the JSON-able report the chaos artifact collects
        assert err.report["operand"] == operand
        assert err.report["error"] == "NumericalHazardError"


class TestSliceOverflow:
    def test_sliced_backend_raises_nonsliced_accepts(self, tmp_cache):
        # |A| ~ 2^1005 overflows the 2^(e+p-beta) extraction anchor on the
        # sliced backends (which would NaN every slice *after* extraction);
        # the same operands are representable, finite work for xla
        rng = np.random.default_rng(7)
        a = mp.from_float(
            jnp.asarray((rng.random((N, N)) + 0.5) * 2.0 ** 1005), "dd")
        b = mp.from_float(
            jnp.asarray((rng.random((N, N)) + 0.5) * 2.0 ** -1005), "dd")
        plan = gemm.make_plan(N, N, N, backend="ozaki", use_cache=False)
        limit = gemm.guard.slice_overflow_limit(plan)
        assert limit is not None and 2.0 ** 1005 > limit
        with pytest.raises(SliceOverflowError) as ei:
            gemm.execute(plan, a, b, check="finite")
        assert ei.value.operand == "A"
        assert ei.value.kind == "overflow"
        assert ei.value.backend == "ozaki"
        # the documented remedy: a non-sliced backend takes the same data
        p_xla = gemm.make_plan(N, N, N, backend="xla", use_cache=False)
        out = gemm.execute(p_xla, a, b, check="finite")
        assert not _any_nonfinite(out)

    def test_nonsliced_plans_have_no_limit(self, tmp_cache):
        for be in ("xla", "ref", "pallas"):
            plan = gemm.make_plan(N, N, N, backend=be, use_cache=False)
            assert gemm.guard.slice_overflow_limit(plan) is None


class TestExtremeScaleExactness:
    """dd arithmetic stays exact at extreme operand scales (PR 9 fix).

    Formerly a documented caveat: the mask split's low part fell into the
    flushed-to-zero subnormal range for operand magnitudes beyond ~2^±996,
    silently costing up to ~2^-25 relative error — the efts pow2 rescue
    now keeps two_prod within its 2^-104 bound there.  The non-sliced
    backends therefore pass check="full" (the f64 shadow gate) on the very
    operands TestSliceOverflow rejects for the sliced ones.
    """

    # the asymmetric band-crossing pairs ((1020, -485) and mirror) pin the
    # _unscale regression where applying the >1 inverse rescue factor first
    # sent a representable 2^535-scale product through 2^1047 == Inf
    @pytest.mark.parametrize("ea,eb", [(1005, -1005), (1000, -1000),
                                       (-1000, 0), (990, -990),
                                       (1020, -485), (-485, 1020)])
    def test_dd_mul_meets_bound_at_extreme_scales(self, ea, eb):
        from fractions import Fraction

        from repro.core import dd

        rng = np.random.default_rng(11)
        av = (rng.random(N * N) + 0.5) * 2.0 ** ea
        bv = (rng.random(N * N) + 0.5) * 2.0 ** eb
        p = dd.mul(dd.from_float(jnp.asarray(av)),
                   dd.from_float(jnp.asarray(bv)))
        hi, lo = np.asarray(p.hi), np.asarray(p.lo)
        worst = 0.0
        for i in range(N * N):
            exact = Fraction(av[i]) * Fraction(bv[i])
            got = Fraction(float(hi[i])) + Fraction(float(lo[i]))
            worst = max(worst, abs(float((got - exact) / exact)))
        # 2^-104 class, plus slack for the FTZ-inherent floor when the
        # error limb itself sits near the subnormal boundary
        inherent = 2.0 ** (-1021 - (ea + eb))  # flushed-limb scale / product
        assert worst <= max(4 * 2.0 ** -104, 4 * inherent), \
            f"dd.mul lost {worst:.3e} relative at scales 2^{ea} x 2^{eb}"

    @pytest.mark.parametrize("ea,eb", [(126, -62), (-62, 126),
                                       (120, -120), (-120, 0)])
    def test_f32_two_prod_meets_bound_at_extreme_scales(self, ea, eb):
        # f32 analogue of the band-crossing regression: (126, -62) used to
        # overflow the _unscale intermediate to Inf despite the 2^64-scale
        # product being comfortably representable
        from fractions import Fraction

        from repro.core import efts

        rng = np.random.default_rng(13)
        av = ((rng.random(N * N) + 0.5) * 2.0 ** ea).astype(np.float32)
        bv = ((rng.random(N * N) + 0.5) * 2.0 ** eb).astype(np.float32)
        p, e = efts.two_prod(jnp.asarray(av), jnp.asarray(bv))
        p, e = np.asarray(p), np.asarray(e)
        assert np.isfinite(p).all() and np.isfinite(e).all()
        worst = 0.0
        for i in range(N * N):
            exact = Fraction(float(av[i])) * Fraction(float(bv[i]))
            got = Fraction(float(p[i])) + Fraction(float(e[i]))
            worst = max(worst, abs(float((got - exact) / exact)))
        inherent = 2.0 ** (-125 - (ea + eb))  # f32 flushed-limb floor
        assert worst <= max(4 * 2.0 ** -46, 4 * inherent), \
            f"f32 two_prod lost {worst:.3e} relative at 2^{ea} x 2^{eb}"

    def test_full_check_passes_at_extreme_scale(self, tmp_cache):
        # the shadow gate used to flag these operands as finite-but-wrong;
        # with the rescue the xla backend's product survives check="full"
        rng = np.random.default_rng(7)
        a = mp.from_float(
            jnp.asarray((rng.random((N, N)) + 0.5) * 2.0 ** 1005), "dd")
        b = mp.from_float(
            jnp.asarray((rng.random((N, N)) + 0.5) * 2.0 ** -1005), "dd")
        plan = gemm.make_plan(N, N, N, backend="xla", use_cache=False)
        out = gemm.execute(plan, a, b, check="full")
        assert not _any_nonfinite(out)
        want = np.asarray(mp.to_float(ddgemm_ref(a, b)))
        got = np.asarray(mp.to_float(out))
        assert np.abs(got - want).max() <= 2.0 ** -40 * np.abs(want).max()


class TestFullCheck:
    def test_clean_pass_with_epilogue(self, tmp_cache):
        a, b = _rand("dd", (N, N), 3), _rand("dd", (N, N), 4)
        c = _rand("dd", (N, N), 5)
        for backend in ("xla", "ozaki", "ozaki-pallas"):
            out = gemm.matmul(a, b, backend=backend, check="full",
                              alpha=0.5, beta=2.0, c=c)
            want = np.asarray(mp.to_float(ddgemm_ref(a, b))) * 0.5 \
                + 2.0 * np.asarray(mp.to_float(c))
            assert np.abs(np.asarray(mp.to_float(out)) - want).max() < 1e-10

    def test_batched_full_check_clean(self, tmp_cache):
        a = _rand("dd", (3, N, N), 6)
        b = _rand("dd", (3, N, N), 7)
        out = gemm.matmul(a, b, backend="xla", check="full")
        assert out.limbs()[0].shape == (3, N, N)


class TestCheckResolution:
    def test_unknown_level_rejected(self, tmp_cache):
        a, b = _rand("dd", (N, N), 8), _rand("dd", (N, N), 9)
        plan = gemm.make_plan(N, N, N, backend="xla", use_cache=False)
        with pytest.raises(ValueError, match="check level"):
            gemm.execute(plan, a, b, check="paranoid")
        with pytest.raises(ValueError, match="check level"):
            gemm.make_plan(N, N, N, check="paranoid")

    def test_plan_field_sets_default_argument_overrides(self, tmp_cache):
        a, b, _ = _hazard_args("dd", "A", "nan")
        plan = gemm.make_plan(N, N, N, backend="xla", check="finite",
                              use_cache=False)
        # plan field alone arms the check...
        with pytest.raises(NumericalHazardError):
            gemm.execute(plan, a, b)
        # ...and the explicit argument wins over the plan field
        out = gemm.execute(plan, a, b, check="none")
        assert _any_nonfinite(out)

    def test_under_outer_jit_degrades_to_propagation(self, tmp_cache):
        # flags are tracers inside a surrounding jit: raising there would
        # poison the shared compiled graph, so the documented behavior is
        # propagation (callers needing hard guarantees run eagerly)
        a, b, _ = _hazard_args("dd", "A", "nan")
        plan = gemm.make_plan(N, N, N, backend="xla", check="finite",
                              use_cache=False)
        f = jax.jit(lambda x, y: gemm.execute(plan, x, y))
        out = f(a, b)
        assert _any_nonfinite(out)
