"""Regression pin for the interpret-mode ozaki-pallas outer-jit quirk.

History (PR 5): wrapping an interpret-mode ozaki-pallas product in an
outer ``jax.jit`` with *closed-over constant* operands produced limbs
that differed from the eager call by ~1e-17 relative (~one dd ulp of the
leading limb, 2^-56 class).  XLA constant-folds the zero-padding of the
operands at trace time with different rounding/fusion choices than the
runtime path, and the interpret-mode Pallas slicing kernel is exactly
sensitive to those last bits.  The old epilogue suite papered over it by
comparing the jitted call against *its own* jitted output.

The fix pins the padded operands behind ``jax.lax.optimization_barrier``
(engine._pad_operand), which forbids the constant-folder from re-deriving
them: jit(const-closure), jit(explicit-args), and eager now agree limb
for limb.  This file is the dedicated pin: every assertion below is
BIT-IDENTICAL (tolerance zero), and the docstrings record the historical
~1e-17 class so a reintroduced drift is recognizable from the failure.

Runs on every tier the ozaki-pallas backend advertises (dd/td/qd) plus
the xla backend as a control — the barrier sits in the shared operand
path, so a regression in either spelling should trip both.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import mp

# odd shapes force real padding: the quirk only ever bit on padded
# operands (unpadded ones are passed through untouched)
_M, _K, _N = 9, 11, 6


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(precision, shape, seed):
    rng = np.random.default_rng(seed)
    out = mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)
    for scale in (2.0 ** -53, 2.0 ** -106, 2.0 ** -159)[: mp.nlimbs(out) - 1]:
        out = mp.add(out, mp.from_float(
            jnp.asarray(rng.standard_normal(shape) * scale), precision))
    return out


def _assert_limbs_equal(got, want, what):
    for i, (lg, lw) in enumerate(zip(mp.limbs(got), mp.limbs(want))):
        np.testing.assert_array_equal(
            np.asarray(lg), np.asarray(lw),
            err_msg=f"{what}: limb {i} drifted (the historical failure "
                    f"was ~1e-17 relative on the leading limb)")


@pytest.mark.parametrize("backend,precision", [
    ("ozaki-pallas", "dd"), ("ozaki-pallas", "td"), ("ozaki-pallas", "qd"),
    ("xla", "dd"), ("xla", "td"),
])
def test_outer_jit_bit_identical_to_eager(backend, precision, tmp_cache):
    """jit(const-closure) == jit(args) == eager, limb for limb."""
    plan = gemm.make_plan(_M, _K, _N, backend=backend, precision=precision)
    a = _rand(precision, (_M, _K), seed=20)
    b = _rand(precision, (_K, _N), seed=21)

    eager = gemm.execute(plan, a, b)

    # the original failure mode: operands are trace-time constants, so
    # the padding is eligible for constant folding
    const_closure = jax.jit(lambda: gemm.execute(plan, a, b))()
    _assert_limbs_equal(const_closure, eager,
                        f"{backend}/{precision} jit(const-closure) vs eager")

    # control: operands as jit arguments (never constant-folded)
    as_args = jax.jit(
        lambda x, y: gemm.execute(plan, x, y))(a, b)
    _assert_limbs_equal(as_args, eager,
                        f"{backend}/{precision} jit(args) vs eager")


def test_outer_jit_with_epilogue_bit_identical(tmp_cache):
    """The fused ozaki-pallas epilogue drain rides the same padded
    operands; alpha/beta/C must not reopen the constant-folding hole."""
    plan = gemm.make_plan(_M, _K, _N, backend="ozaki-pallas")
    a = _rand("dd", (_M, _K), seed=22)
    b = _rand("dd", (_K, _N), seed=23)
    c = _rand("dd", (_M, _N), seed=24)

    def run():
        return gemm.execute(plan, a, b, alpha=0.5, beta=-2.0, c=c)

    _assert_limbs_equal(jax.jit(run)(), run(),
                        "ozaki-pallas epilogue jit(const-closure) vs eager")
