"""LAPACK-grade residual gates for the extended-precision linalg stack.

The classic LAPACK test ratios, at every ladder rung and with *exact*
measurement: factorization residuals are evaluated in rational arithmetic
(``core.accuracy``'s Fraction helpers) over the representable multi-limb
entries, so the gate pins the factorization's own backward error with
zero measurement noise:

    rgetrf:  ||P A - L U||  / (n ||A|| u_tier)  <= THRESH
    rpotrf:  ||A - L L^T||  / (n ||A|| u_tier)  <= THRESH
    rgesv :  ||A x - b|| / (||A|| ||x|| + ||b||) <= 4 n u_tier

THRESH = 30 is LAPACK's own acceptance constant.  Matrices cover the
well-conditioned case and the two canonical ill-conditioned families —
Hilbert (cond ~ e^{3.5n}) and graded-diagonal (rows spanning ~12 decades)
— because backward-stability gates must hold *independently of
conditioning*; that is precisely what they certify.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mp
from repro.core.accuracy import (
    frac_matmul,
    frac_matrix,
    frac_max_abs,
    frac_sub,
    hilbert_f64,
)
from repro.core.linalg import apply_pivots, rgetrf, rpotrf
from repro.solve import rgesv, rposv, tier_eps

pytestmark = pytest.mark.solver

THRESH = 30.0  # LAPACK's standard residual-ratio acceptance constant
TIERS = ("dd", "qd")
N = 10  # Fraction arithmetic is O(n^3) with ~tier-width operands


def _well_conditioned(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) + n * np.eye(n)


def _graded(n: int, seed: int = 1) -> np.ndarray:
    """Graded-diagonal matrix: row scales spanning ~12 decades."""
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((n, n)) + n * np.eye(n)
    scales = np.logspace(0, -12, n)
    return scales[:, None] * g


MATRICES = {
    "rand": _well_conditioned(N),
    "hilbert": hilbert_f64(N),
    "graded": _graded(N),
}


def _spd(a: np.ndarray) -> np.ndarray:
    return a @ a.T + len(a) * np.eye(len(a))


SPD_MATRICES = {
    "rand": _spd(_well_conditioned(N)),
    "hilbert": hilbert_f64(N),  # already SPD
    "graded": _spd(_graded(N)) * np.outer(np.logspace(0, -6, N),
                                          np.logspace(0, -6, N)),
}


def _tri_parts(lu, n: int):
    """Split packed L\\U into unit-lower L and upper U, in tier arithmetic."""
    tril = jnp.asarray(np.tril(np.ones((n, n)), -1))
    triu = jnp.asarray(np.triu(np.ones((n, n))))
    eye = jnp.eye(n)
    l = mp.from_limbs([lim * tril + (eye if i == 0 else 0.0)
                       for i, lim in enumerate(mp.limbs(lu))])
    u = mp.map_limbs(lambda lim: lim * triu, lu)
    return l, u


@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("name", sorted(MATRICES))
def test_rgetrf_residual_gate(tier, name):
    a_np = MATRICES[name]
    a = mp.from_float(jnp.asarray(a_np), tier)
    lu, piv = rgetrf(a, block=4)
    l, u = _tri_parts(lu, N)
    pa = apply_pivots(a, piv)
    resid = frac_sub(frac_matrix(pa), frac_matmul(frac_matrix(l),
                                                  frac_matrix(u)))
    anorm = frac_max_abs(frac_matrix(a))
    ratio = frac_max_abs(resid) / (N * anorm * tier_eps(tier))
    assert ratio <= THRESH, (name, tier, ratio)


@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("name", sorted(SPD_MATRICES))
def test_rpotrf_residual_gate(tier, name):
    a_np = SPD_MATRICES[name]
    a = mp.from_float(jnp.asarray(a_np), tier)
    l = rpotrf(a)
    fl = frac_matrix(l)
    flt = [list(row) for row in zip(*fl)]
    resid = frac_sub(frac_matrix(a), frac_matmul(fl, flt))
    anorm = frac_max_abs(frac_matrix(a))
    ratio = frac_max_abs(resid) / (N * anorm * tier_eps(tier))
    assert ratio <= THRESH, (name, tier, ratio)


@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("name", sorted(MATRICES))
def test_rgesv_backward_error_gate(tier, name):
    a_np = MATRICES[name]
    rng = np.random.default_rng(7)
    b_np = a_np @ rng.standard_normal((N, 2))
    x, info = rgesv(a_np, b_np, factor_tier="f64", target_tier=tier,
                    backend="xla", max_iters=30)
    assert info.converged, (name, tier, info.backward_errors)
    # independent exact-rational residual of the returned iterate
    a_t = mp.from_float(jnp.asarray(a_np), tier)
    b_t = mp.from_float(jnp.asarray(b_np), tier)
    resid = frac_sub(frac_matmul(frac_matrix(a_t), frac_matrix(x)),
                     frac_matrix(b_t))
    anorm = float(np.abs(a_np).max())
    xnorm = float(np.abs(np.asarray(mp.to_float(x))).max())
    bnorm = float(np.abs(b_np).max())
    berr = frac_max_abs(resid) / (anorm * xnorm + bnorm)
    assert berr <= 4 * N * tier_eps(tier), (name, tier, berr)


@pytest.mark.parametrize("tier", TIERS)
def test_rposv_backward_error_gate(tier):
    a_np = SPD_MATRICES["rand"]
    rng = np.random.default_rng(9)
    b_np = a_np @ rng.standard_normal((N, 2))
    x, info = rposv(a_np, b_np, factor_tier="f64", target_tier=tier,
                    backend="xla", max_iters=30)
    assert info.converged
    r = a_np @ np.asarray(mp.to_float(x)) - b_np  # f64 check only
    assert np.abs(r).max() < 1e-12  # exact gate covered by rgesv above
    assert info.final_backward_error <= 4 * N * tier_eps(tier)
