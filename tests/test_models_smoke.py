"""Per-arch smoke tests: REDUCED config of the same family, one forward /
train-loss + one decode step on CPU, asserting shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.configs.registry import ALL_ARCHS
from repro.models import model as M


def reduce_cfg(cfg):
    """Shrink every size knob while preserving the family's structure."""
    changes = dict(
        n_layers=max(2, (cfg.attn_every or cfg.slstm_every or
                         cfg.cross_attn_every or 2)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.family == "moe":
        changes.update(n_experts=4, experts_per_token=2,
                       n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family == "ssm":
        changes.update(n_layers=2 * cfg.slstm_every)
    if cfg.family == "hybrid":
        changes.update(n_layers=2 * cfg.attn_every, ssm_state=8)
    if cfg.family == "vlm":
        changes.update(n_layers=2 * cfg.cross_attn_every, n_modality_tokens=8)
    return dataclasses.replace(cfg, **changes)


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "audio":
        batch["features"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_modality_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = reduce_cfg(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = M.forward_logits(params, cfg, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab_size), logits.shape
    assert not bool(jnp.isnan(logits).any()), "NaN logits"
    loss, parts = M.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grads_finite(arch):
    cfg = reduce_cfg(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, b=2, s=16)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_loss(p, cfg, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert not bool(jnp.isnan(g).any()), "NaN grad"
    # at least some gradient signal
    total = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert total > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_config(a).encoder_only])
def test_decode_step(arch):
    cfg = reduce_cfg(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, max_len = 2, 16
    state = M.init_decode_state(cfg, b, max_len)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    logits, state = M.decode_step(params, cfg, state, tok, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    logits2, state = M.decode_step(params, cfg, state, tok, jnp.int32(1))
    assert not bool(jnp.isnan(logits2).any())
    # state must actually change the distribution
    assert float(jnp.abs(logits2 - logits).max()) > 0


@pytest.mark.parametrize("arch", ["qwen3-4b", "xlstm-350m", "zamba2-2.7b"])
def test_prefill_decode_consistency(arch):
    """Greedy: decode steps must match teacher-forced full forward."""
    cfg = reduce_cfg(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    b, s = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full_logits, _ = M.forward_logits(params, cfg, {"tokens": toks})
    state = M.init_decode_state(cfg, b, s + 1)
    outs = []
    for t in range(s):
        lg, state = M.decode_step(params, cfg, state, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2)


def test_input_specs_all_cells():
    from repro.configs.shapes import skip_reason

    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for sh in SHAPES.values():
            if skip_reason(cfg, sh):
                continue
            specs = M.input_specs(cfg, sh)
            assert all(hasattr(v, "shape") for v in specs.values())
