"""Ozaki-scheme GEMM: exactness of slicing + accuracy vs the DD oracle."""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dd, ozaki
from repro.kernels.ref import ddgemm_ref


def _rand_dd(shape, rng, scale_lo=1e-20):
    hi = rng.standard_normal(shape)
    x = dd.from_float(jnp.asarray(hi))
    lo = rng.standard_normal(shape) * scale_lo
    return dd.add(x, dd.from_float(jnp.asarray(lo)))


def _max_rel_err(got: dd.DD, want: dd.DD):
    diff = np.abs(
        (np.asarray(got.hi, np.float64) - np.asarray(want.hi, np.float64))
        + (np.asarray(got.lo, np.float64) - np.asarray(want.lo, np.float64))
    )
    scale = np.maximum(np.abs(np.asarray(want.hi, np.float64)), 1e-30)
    return float((diff / scale).max())


def test_slice_extraction_is_error_free():
    rng = np.random.default_rng(0)
    a = _rand_dd((8, 16), rng)
    beta = 10
    slices = ozaki._extract_slices(a, beta, 12, axis=1)
    # slices must sum back to a (within the dropped remainder < 2^(-beta*12))
    total = dd.zeros(a.shape, jnp.float64)
    for s in slices:
        total = dd.add(total, dd.from_float(s))
    assert _max_rel_err(total, a) < 2.0 ** (-beta * 11)
    # each slice entry has <= beta+1 significant bits (grid-aligned)
    for s in slices:
        s_np = np.asarray(s)
        nz = s_np[s_np != 0]
        for v in nz[:50]:
            m, e = np.frexp(v)
            # value / its own grid must be a small integer
            assert float(m) * 2 ** (beta + 1) == int(float(m) * 2 ** (beta + 1))


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 64, 12), (33, 128, 17)])
def test_ozaki_f64_matches_oracle(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = _rand_dd((m, k), rng)
    b = _rand_dd((k, n), rng)
    got = ozaki.ozaki_gemm(a, b)
    want = ddgemm_ref(a, b)
    assert _max_rel_err(got, want) < 2.0**-95


def test_ozaki_badly_scaled_rows():
    # per-row grids must handle rows of wildly different magnitude
    rng = np.random.default_rng(7)
    a_np = rng.standard_normal((8, 32)) * (10.0 ** rng.integers(-18, 18, size=(8, 1)))
    b_np = rng.standard_normal((32, 8)) * (10.0 ** rng.integers(-18, 18, size=(1, 8)))
    a, b = dd.from_float(jnp.asarray(a_np)), dd.from_float(jnp.asarray(b_np))
    got = ozaki.ozaki_gemm(a, b)
    want = ddgemm_ref(a, b)
    assert _max_rel_err(got, want) < 2.0**-90


def test_ozaki_bf16_slices_small_k():
    # the MXU path: bf16 slices, f32 accumulation; k small enough for beta=8
    rng = np.random.default_rng(5)
    a = _rand_dd((16, 32), rng)
    b = _rand_dd((32, 16), rng)
    got = ozaki.ozaki_gemm(a, b, slice_dtype=jnp.bfloat16, acc_dtype=jnp.float32)
    want = ddgemm_ref(a, b)
    assert _max_rel_err(got, want) < 2.0**-90


def test_ozaki_full_vs_truncated():
    rng = np.random.default_rng(9)
    a = _rand_dd((8, 16), rng)
    b = _rand_dd((16, 8), rng)
    got_tri = ozaki.ozaki_gemm(a, b, full=False)
    got_full = ozaki.ozaki_gemm(a, b, full=True)
    want = ddgemm_ref(a, b)
    assert _max_rel_err(got_full, want) <= 2.0**-100
    assert _max_rel_err(got_tri, want) < 2.0**-95


def test_ozaki_exact_on_f64_inputs_small():
    # pure f64 inputs (lo = 0), tiny k: against exact Fraction products
    rng = np.random.default_rng(2)
    a_np = rng.standard_normal((4, 4))
    b_np = rng.standard_normal((4, 4))
    got = ozaki.ozaki_gemm(dd.from_float(jnp.asarray(a_np)), dd.from_float(jnp.asarray(b_np)), full=True)
    for i in range(4):
        for j in range(4):
            want = sum((Fraction(a_np[i, p]) * Fraction(b_np[p, j]) for p in range(4)), Fraction(0))
            gotf = Fraction(float(got.hi[i, j])) + Fraction(float(got.lo[i, j]))
            assert abs(float(gotf - want)) <= 2.0**-100 * max(1.0, abs(float(want)))


def test_slice_bits_and_count():
    assert ozaki.slice_bits(4096, jnp.float32, jnp.bfloat16) == 6
    assert ozaki.slice_bits(64, jnp.float32, jnp.bfloat16) == 8
    assert ozaki.slice_bits(256, jnp.float64) == 22
    assert ozaki.slice_count(107, 6) == 19
