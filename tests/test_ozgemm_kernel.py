"""Fused Ozaki-slice Pallas kernel + its plan/engine/autotune plumbing.

Covers the ISSUE-3 acceptance surface beyond the conformance matrix:
block-shape sweeps (including slabs that force K padding), the in-drain
alpha/beta epilogue vs the post-step form, the bf16-slice/f32-acc MXU
configuration exercised on CPU interpret, qd-tier slab recombination, the
plan as the single source of slice parameters, the too-deep-K fallback to
xla, and the n_slices-aware autotune cache round-trip.
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import gemm
from repro.core import dd, mp, ozaki
from repro.core.accuracy import max_rel_err as _rel_err
from repro.core.blas import rgemm
from repro.kernels.ref import ddgemm_ref, qdgemm_ref


@pytest.fixture()
def tmp_cache(tmp_path):
    cache = gemm.PlanCache(str(tmp_path / "plans.json"))
    gemm.set_default_cache(cache)
    yield cache
    gemm.set_default_cache(None)


def _rand(precision, shape, seed):
    rng = np.random.default_rng(seed)
    out = mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)
    for scale in (2.0 ** -53, 2.0 ** -106, 2.0 ** -159)[: mp.nlimbs(out) - 1]:
        out = mp.add(out, mp.from_float(
            jnp.asarray(rng.standard_normal(shape) * scale), precision))
    return out


@pytest.mark.parametrize("blocks", [
    dict(bm=8, bn=8, bk=8),       # many tiles, K padded (k=20 -> 24)
    dict(bm=16, bn=8, bk=16),     # uneven tiles
    dict(bm=32, bn=32, bk=8),     # single M/N tile, K streamed
])
def test_block_sweep_matches_oracle(blocks, tmp_cache):
    m, k, n = 19, 20, 11
    a, b = _rand("dd", (m, k), 1), _rand("dd", (k, n), 2)
    got = gemm.matmul(a, b, backend="ozaki-pallas", **blocks)
    assert _rel_err(got, ddgemm_ref(a, b)) < 16 * k * 2.0 ** -104


def test_qd_tier_slab_recombination(tmp_cache):
    m, k, n = 10, 24, 9
    a, b = _rand("qd", (m, k), 3), _rand("qd", (k, n), 4)
    plan = gemm.make_plan(m, k, n, backend="ozaki-pallas", precision="qd")
    # the qd tier targets ~212 bits: the slab fixpoint must cover them
    assert plan.target_bits == 212
    assert plan.slice_beta * plan.n_slices >= 212
    got = gemm.execute(plan, a, b)
    assert _rel_err(got, qdgemm_ref(a, b)) < 16 * k * 2.0 ** -205


def test_fused_epilogue_matches_post_step(tmp_cache):
    m, k, n = 9, 17, 7
    a, b, c = _rand("dd", (m, k), 5), _rand("dd", (k, n), 6), \
        _rand("dd", (m, n), 7)
    one = mp.from_float(jnp.asarray(1.0), "dd")
    alpha = mp.div(one, mp.from_float(jnp.asarray(3.0), "dd"))
    beta = mp.div(mp.neg(one), mp.from_float(jnp.asarray(7.0), "dd"))
    # fused: ozaki-pallas applies alpha/beta inside the kernel drain
    got = rgemm("n", "n", alpha, a, b, beta, c, backend="ozaki-pallas")
    # post-step oracle: ref product + identical tier epilogue
    prod = ddgemm_ref(a, b)
    want = mp.add(mp.mul(mp.broadcast_to(alpha, prod.shape), prod),
                  mp.mul(mp.broadcast_to(beta, c.shape), c))
    assert _rel_err(got, want) < 16 * k * 2.0 ** -104
    # alpha-only fusion (no C term)
    got_a = rgemm("n", "n", alpha, a, b, 0.0, backend="ozaki-pallas")
    want_a = mp.mul(mp.broadcast_to(alpha, prod.shape), prod)
    assert _rel_err(got_a, want_a) < 16 * k * 2.0 ** -104


def test_bf16_slices_f32_acc_on_interpret(tmp_cache):
    # the real-TPU MXU configuration, validated on CPU interpret: bf16
    # slices, f32 accumulation, per-row shared power-of-two scaling
    m, k, n = 12, 16, 10
    a, b = _rand("dd", (m, k), 8), _rand("dd", (k, n), 9)
    got = gemm.matmul(a, b, backend="ozaki-pallas",
                      slice_dtype=jnp.bfloat16, acc_dtype=jnp.float32)
    # bf16 slices carry ~8 bits each: coverage is capped by the slab
    # fixpoint, still far beyond one native dot
    assert _rel_err(got, ddgemm_ref(a, b)) < 2.0 ** -90


def test_plan_is_single_source_of_slice_params(tmp_cache):
    plan = gemm.make_plan(16, 32, 16, backend="ozaki-pallas")
    # the plan carries the solved pair; the engine consumes, never re-derives
    want = ozaki.slice_params(plan.bk, jnp.dtype(plan.acc_dtype),
                              jnp.dtype(plan.slice_dtype),
                              target_bits=plan.target_bits)
    assert (plan.slice_beta, plan.n_slices) == want
    # the whole-K path stores its own depth's parameters
    plan_xla_oz = gemm.make_plan(16, 32, 16, backend="ozaki")
    want = ozaki.slice_params(32, jnp.dtype(plan_xla_oz.acc_dtype),
                              jnp.dtype(plan_xla_oz.slice_dtype),
                              target_bits=plan_xla_oz.target_bits)
    assert (plan_xla_oz.slice_beta, plan_xla_oz.n_slices) == want
    # a pinned n_slices survives planning and still solves beta for it
    pinned = gemm.make_plan(16, 32, 16, backend="ozaki-pallas", n_slices=7)
    assert pinned.n_slices == 7 and pinned.slice_beta >= 1


def test_too_deep_k_falls_back_to_xla(tmp_cache):
    # f32 accumulation over k > 2^22 leaves no exact slice bits: the plan
    # must degrade to the portable xla backend with a warning, not raise
    with pytest.warns(RuntimeWarning, match="falling back"):
        plan = gemm.make_plan(8, 1 << 23, 8, backend="ozaki",
                              acc_dtype=jnp.float32,
                              slice_dtype=jnp.float32)
    assert plan.backend == "xla"
    assert plan.n_slices is None and plan.slice_beta is None
    # feasible depths never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = gemm.make_plan(8, 64, 8, backend="ozaki")
    assert plan.backend == "ozaki"


def test_autotune_persists_n_slices(tmp_cache):
    plan = gemm.autotune(24, 24, 24, backend="ozaki-pallas",
                         candidates=[{"bm": 8, "bn": 8, "bk": 8},
                                     {"bm": 24, "bn": 24, "bk": 8,
                                      "n_slices": 6}],
                         iters=1)
    assert plan.source == "tuned" and plan.backend == "ozaki-pallas"
    key = gemm.cache_key("cpu", "float64", 24, 24, 24, "ozaki-pallas")
    entry = tmp_cache.get(key)
    assert entry is not None and entry["n_slices"] == plan.n_slices
    # the planner adopts blocks AND slice count from the tuned entry
    replanned = gemm.make_plan(24, 24, 24, backend="ozaki-pallas",
                               platform="cpu")
    assert replanned.source == "tuned"
    assert (replanned.bm, replanned.bn, replanned.bk, replanned.n_slices) \
        == (plan.bm, plan.bn, plan.bk, plan.n_slices)


def test_tuned_n_slices_not_adopted_under_dtype_override(tmp_cache):
    # a slice count tuned for f64/f64 covers ~5*23 bits; with bf16 slices
    # beta caps at 8, so adopting it would silently lose ~70 bits — the
    # planner must re-solve when the caller overrides slice/acc dtypes
    key = gemm.cache_key("cpu", "float64", 24, 24, 24, "ozaki-pallas")
    tmp_cache.put(key, {"bm": 24, "bn": 24, "bk": 8, "n_slices": 5})
    plan = gemm.make_plan(24, 24, 24, backend="ozaki-pallas",
                          platform="cpu", slice_dtype=jnp.bfloat16,
                          acc_dtype=jnp.float32)
    assert plan.slice_beta * plan.n_slices >= 107


def test_pinned_beta_past_exactness_ceiling_raises(tmp_cache):
    # a pinned beta violating 2*beta + log2(k*s) <= p_acc would silently
    # break the exact native summation: it must be rejected at entry
    a, b = _rand("dd", (8, 16), 19), _rand("dd", (16, 8), 20)
    with pytest.raises(ValueError, match="exact accumulation"):
        ozaki.ozaki_gemm(a, b, beta=26)


def test_cache_key_schema_versioned(tmp_cache):
    # the v2 schema bump orphans pre-n_slices entries instead of misreading
    from repro.gemm.cache import SCHEMA

    key = gemm.cache_key("cpu", "float64", 64, 64, 64, "ozaki-pallas")
    assert key.startswith(f"v{SCHEMA}/")


def test_bf16_ladder_survives_tiny_rows(tmp_cache):
    # ladder normalization: slice i is scaled by 2^(i*beta) back to O(1),
    # so deep slices of tiny rows do NOT underflow the narrow dtype (a
    # single shared scale would leave slice i at 2^(-i*beta) relative,
    # flushing the low end of the ladder to zero)
    rng = np.random.default_rng(12)
    a_np = rng.standard_normal((8, 16)) * 1e-30
    b_np = rng.standard_normal((16, 8)) * 1e+25
    a = dd.from_float(jnp.asarray(a_np))
    b = dd.from_float(jnp.asarray(b_np))
    got = gemm.matmul(a, b, backend="ozaki-pallas",
                      slice_dtype=jnp.bfloat16, acc_dtype=jnp.float32)
    assert _rel_err(got, ddgemm_ref(a, b)) < 2.0 ** -90


def test_full_flag_reaches_the_kernel(tmp_cache):
    # full=True keeps the sub-target slice products: on pure-f64 inputs the
    # full accumulation is (near-)exact, visibly better than truncated
    rng = np.random.default_rng(13)
    a = dd.from_float(jnp.asarray(rng.standard_normal((8, 12))))
    b = dd.from_float(jnp.asarray(rng.standard_normal((12, 8))))
    want = ddgemm_ref(a, b)
    got_full = gemm.matmul(a, b, backend="ozaki-pallas", full=True,
                           bm=8, bn=8, bk=16)
    assert _rel_err(got_full, want) <= 2.0 ** -100


def test_matmul_c_without_beta_adds_c(tmp_cache):
    # c= without beta= must ADD C (beta defaults to 1), never drop it
    a, b, c = _rand("dd", (6, 5), 14), _rand("dd", (5, 4), 15), \
        _rand("dd", (6, 4), 16)
    got = gemm.matmul(a, b, c=c, backend="xla")
    want = mp.add(ddgemm_ref(a, b), c)
    assert _rel_err(got, want) < 16 * 5 * 2.0 ** -104


def test_ozaki_gemm_accepts_pinned_beta(tmp_cache):
    # beta= without n_slices= solves the slice count instead of crashing
    a, b = _rand("dd", (8, 16), 17), _rand("dd", (16, 8), 18)
    got = ozaki.ozaki_gemm(a, b, beta=20)
    assert _rel_err(got, ddgemm_ref(a, b)) < 16 * 16 * 2.0 ** -104


def test_sharded_single_device_mesh(tmp_cache):
    # row-sharded execution runs the fused kernel per device panel
    from jax.sharding import Mesh
    import jax

    mesh = Mesh(np.array(jax.devices()[:1]), ("rows",))
    a, b = _rand("dd", (26, 10), 10), _rand("dd", (10, 18), 11)
    got = gemm.matmul(a, b, backend="ozaki-pallas", mesh=mesh)
    assert _rel_err(got, ddgemm_ref(a, b)) < 16 * 10 * 2.0 ** -104


def test_diagonal_grouping_is_exact_on_worst_case(tmp_cache):
    # all-positive operands maximize carry propagation in the grouped
    # native sums: any span overflow past p_acc shows up as lost bits here
    rng = np.random.default_rng(11)
    k = 64
    a = dd.from_float(jnp.asarray(rng.random((16, k))))
    b = dd.from_float(jnp.asarray(rng.random((k, 16))))
    for backend in ("ozaki", "ozaki-pallas"):
        got = gemm.matmul(a, b, backend=backend)
        assert _rel_err(got, ddgemm_ref(a, b)) < 16 * k * 2.0 ** -104, backend
