"""Property suite for the pivot / TRSM layer under the refinement solver.

Hypothesis-driven invariants (plus deterministic spot checks that run even
without hypothesis installed):

  * ``apply_pivots`` round-trip — forward interchanges followed by the
    inverse application is the identity, for any LAPACK-style pivot vector
    (piv[j] >= j) and any offset;
  * ``rtrsm`` left/right x unit/non-unit consistency — the returned X
    reproduces B through the *mp oracle* (a tier-arithmetic reference
    product), and the right-side solve agrees with the transpose identity;
  * ``rgetrf2`` (unblocked) and ``rgetrf(block=nb)`` agree — same pivots,
    same packed L\\U to tier accuracy — across random panel widths.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mp
from repro.core.accuracy import max_rel_err
from repro.core.linalg import (
    apply_pivots,
    pivot_permutation,
    rgetrf,
    rgetrf2,
    rtrsm,
)
from repro.kernels.ref import ddgemm_ref, qdgemm_ref

pytestmark = pytest.mark.solver

REF = {"dd": ddgemm_ref, "qd": qdgemm_ref}
ULP = {"dd": 2.0 ** -104, "qd": 2.0 ** -205}


def _rand(precision, shape, seed):
    rng = np.random.default_rng(seed)
    return mp.from_float(jnp.asarray(rng.standard_normal(shape)), precision)


def _rand_piv(rng, m):
    """LAPACK-style interchange vector: piv[j] in [j, m)."""
    return np.array([rng.integers(j, m) for j in range(m)], np.int32)


def _tri(rng, n, *, lower, unit_diag):
    t = rng.standard_normal((n, n))
    t = np.tril(t) if lower else np.triu(t)
    np.fill_diagonal(t, 1.0 if unit_diag else 3.0 + rng.random(n))
    return t


# -- deterministic spot checks (always run) --------------------------------


@pytest.mark.parametrize("precision", ["dd", "qd"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_apply_pivots_roundtrip(precision, seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 12))
    x = _rand(precision, (m, 3), seed)
    piv = jnp.asarray(_rand_piv(rng, m))
    back = apply_pivots(apply_pivots(x, piv), piv, inverse=True)
    assert max_rel_err(back, x) == 0.0  # pure gathers: bit-exact


@pytest.mark.parametrize("offset", [0, 2])
def test_pivot_permutation_matches_legacy_loop(offset):
    rng = np.random.default_rng(3)
    m, nb = 9, 5
    piv = _rand_piv(rng, nb)  # local panel pivots
    perm = np.arange(m)
    for j, p in enumerate(piv):  # the pre-traceable reference construction
        jj, pj = j + offset, int(p) + offset
        perm[jj], perm[pj] = perm[pj], perm[jj]
    got = np.asarray(pivot_permutation(jnp.asarray(piv), m, offset))
    assert (got == perm).all()


@pytest.mark.parametrize("precision", ["dd", "qd"])
@pytest.mark.parametrize("side", ["left", "right"])
@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("unit_diag", [True, False])
def test_rtrsm_consistency_vs_mp_oracle(precision, side, lower, unit_diag):
    rng = np.random.default_rng(11)
    n, k = 7, 4
    t_np = _tri(rng, n, lower=lower, unit_diag=unit_diag)
    t = mp.from_float(jnp.asarray(t_np), precision)
    bshape = (n, k) if side == "left" else (k, n)
    b = _rand(precision, bshape, 13)
    x = rtrsm(t, b, side=side, lower=lower, unit_diag=unit_diag)
    # mp oracle: op(T) X (or X op(T)) must reproduce B in tier arithmetic
    recon = REF[precision](t, x) if side == "left" else REF[precision](x, t)
    assert max_rel_err(recon, b) < 64 * n * ULP[precision]


def test_rtrsm_right_agrees_with_transpose_identity():
    rng = np.random.default_rng(17)
    n, k = 6, 3
    t = mp.from_float(jnp.asarray(_tri(rng, n, lower=True,
                                       unit_diag=False)), "dd")
    b = _rand("dd", (k, n), 19)
    via_right = rtrsm(t, b, side="right", lower=True)
    bt = mp.map_limbs(lambda l: l.T, b)
    via_left = rtrsm(t, bt, lower=True, transpose_a=True)
    assert max_rel_err(via_right, mp.map_limbs(lambda l: l.T, via_left)) == 0.0


def test_rtrsm_rejects_unknown_side():
    t = _rand("dd", (4, 4), 23)
    with pytest.raises(ValueError, match="side"):
        rtrsm(t, t, side="middle")


@pytest.mark.parametrize("precision", ["dd", "qd"])
@pytest.mark.parametrize("n,nb", [(8, 3), (12, 5), (9, 9), (10, 4)])
def test_rgetrf_blocked_matches_unblocked(precision, n, nb):
    a = _rand(precision, (n, n), n * 7 + nb)
    full, piv_full = rgetrf2(a)
    blocked, piv_blk = rgetrf(a, block=nb)
    assert (np.asarray(piv_full) == np.asarray(piv_blk)).all()
    assert max_rel_err(blocked, full) < 64 * n * ULP[precision]


# -- hypothesis properties (skipped when hypothesis is unavailable; the
# deterministic spot checks above run regardless, so the layer is never
# entirely unexercised) ----------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _piv_cases(draw):
        m = draw(st.integers(min_value=1, max_value=16))
        piv = [draw(st.integers(min_value=j, max_value=m - 1))
               for j in range(m)]
        return m, np.array(piv, np.int32)

    @given(_piv_cases(), st.sampled_from(["dd", "qd"]))
    @settings(max_examples=25, deadline=None)
    def test_prop_apply_pivots_roundtrip(case, precision):
        m, piv = case
        x = _rand(precision, (m, 2), m)
        back = apply_pivots(apply_pivots(x, jnp.asarray(piv)),
                            jnp.asarray(piv), inverse=True)
        assert max_rel_err(back, x) == 0.0

    @given(st.integers(min_value=2, max_value=10),
           st.booleans(), st.booleans(), st.sampled_from(["left", "right"]),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_prop_rtrsm_reconstructs_b(n, lower, unit_diag, side, seed):
        rng = np.random.default_rng(seed)
        t = mp.from_float(jnp.asarray(_tri(rng, n, lower=lower,
                                           unit_diag=unit_diag)), "dd")
        bshape = (n, 3) if side == "left" else (3, n)
        b = _rand("dd", bshape, seed % 1000)
        x = rtrsm(t, b, side=side, lower=lower, unit_diag=unit_diag)
        recon = REF["dd"](t, x) if side == "left" else REF["dd"](x, t)
        assert max_rel_err(recon, b) < 64 * n * ULP["dd"]

    @given(st.integers(min_value=2, max_value=14),
           st.integers(min_value=1, max_value=14),
           st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_prop_rgetrf_block_invariance(n, nb, seed):
        a = _rand("dd", (n, n), seed % 10_000)
        full, piv_full = rgetrf2(a)
        blocked, piv_blk = rgetrf(a, block=min(nb, n))
        assert (np.asarray(piv_full) == np.asarray(piv_blk)).all()
        assert max_rel_err(blocked, full) < 64 * n * ULP["dd"]
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(pip install -r requirements-dev.txt)")
    def test_prop_suite_requires_hypothesis():
        pass
