"""Property tests for quad-word arithmetic: must exceed binary128 (113-bit)."""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dd, qd

# normal-range magnitudes only (XLA CPU flushes subnormals; see efts.py)
finite = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-1e50, max_value=1e50
).filter(lambda x: x == 0.0 or abs(x) > 1e-50)

# binary128 unit roundoff is 2^-113; qd64 must beat it with margin.
QD_TARGET = 2.0**-150


def _qd_frac(x: qd.QD) -> Fraction:
    return sum((Fraction(float(l)) for l in x.limbs()), Fraction(0))


def _rel(got: Fraction, want: Fraction) -> float:
    if want == 0:
        return float(abs(got))
    return abs(float((got - want) / want))


@settings(max_examples=100, deadline=None)
@given(finite, finite)
def test_add_beats_binary128(a, b):
    qa, qb = qd.from_float(jnp.float64(a)), qd.from_float(jnp.float64(b))
    got = _qd_frac(qd.add(qa, qb))
    assert _rel(got, Fraction(a) + Fraction(b)) <= QD_TARGET


@settings(max_examples=100, deadline=None)
@given(finite, finite)
def test_mul_beats_binary128(a, b):
    qa, qb = qd.from_float(jnp.float64(a)), qd.from_float(jnp.float64(b))
    got = _qd_frac(qd.mul(qa, qb))
    want = Fraction(a) * Fraction(b)
    # product of two f64 values fits in 106 bits -> should be (near-)exact
    assert _rel(got, want) <= QD_TARGET


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_mul_of_dd_inputs(a, b, c, e):
    qa = qd.from_dd(dd.add(dd.from_float(jnp.float64(a)), dd.from_float(jnp.float64(b * 1e-18))))
    qb = qd.from_dd(dd.add(dd.from_float(jnp.float64(c)), dd.from_float(jnp.float64(e * 1e-18))))
    got = _qd_frac(qd.mul(qa, qb))
    want = _qd_frac(qa) * _qd_frac(qb)
    assert _rel(got, want) <= QD_TARGET


def test_accumulation_chain_precision():
    # Accumulate 512 products; relative error must stay far below 2^-113.
    rng = np.random.default_rng(0)
    a = rng.standard_normal(512)
    b = rng.standard_normal(512)
    acc = qd.from_float(jnp.float64(0.0))
    va = qd.from_float(jnp.asarray(a))
    vb = qd.from_float(jnp.asarray(b))
    prod = qd.mul(va, vb)
    # tree-free sequential fold in one vectorized shot: use renorm over limbs
    # by summing with qd.add pairwise halving
    cur = prod
    m = 512
    while m > 1:
        half = m // 2
        cur = qd.add(qd.QD(*[l[:half] for l in cur.limbs()]), qd.QD(*[l[half : 2 * half] for l in cur.limbs()]))
        m = half
    got = _qd_frac(qd.QD(*[l[0] for l in cur.limbs()]))
    want = sum((Fraction(x) * Fraction(y) for x, y in zip(a, b)), Fraction(0))
    assert _rel(got, want) < 2.0**-140


def test_to_dd_roundtrip():
    q = qd.from_float(jnp.float64(3.5))
    d = qd.to_dd(q)
    assert float(dd.to_float(d)) == 3.5


# --------------------------------------------------------------------------
# property tests for the qd tier's engine-facing contract (ISSUE-2):
# associativity error bounds, renorm idempotence, dd round-trips, div/sqrt
# --------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite)
def test_add_associativity_error_bound(a, b, c):
    # floating add is not associative; QD add must keep BOTH parenthesizations
    # within the format's eps of the exact sum (so accumulation order inside
    # the engine's tree reductions cannot cost observable bits)
    qa, qb, qc = (qd.from_float(jnp.float64(v)) for v in (a, b, c))
    want = Fraction(a) + Fraction(b) + Fraction(c)
    left = _qd_frac(qd.add(qd.add(qa, qb), qc))
    right = _qd_frac(qd.add(qa, qd.add(qb, qc)))
    assert _rel(left, want) <= QD_TARGET
    assert _rel(right, want) <= QD_TARGET


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_renorm_idempotence(a, b, c, e):
    # renormalizing an already-renormalized expansion is the identity,
    # limb for limb (the canonical-form fixed point the kernels rely on)
    terms = [jnp.float64(a), jnp.float64(b * 1e-16),
             jnp.float64(c * 1e-32), jnp.float64(e * 1e-48)]
    once = qd.renorm_list(terms, k=4, sweeps=3)
    twice = qd.renorm_list(once, k=4, sweeps=3)
    for l1, l2 in zip(once, twice):
        assert float(l1) == float(l2) or (
            np.isnan(float(l1)) and np.isnan(float(l2)))


@settings(max_examples=100, deadline=None)
@given(finite, finite)
def test_from_dd_to_dd_roundtrip_exact(a, b):
    # lifting a canonical DD into QD and dropping back must be EXACT:
    # the two extra limbs are zeros, to_dd re-distills the same pair
    d = dd.add(dd.from_float(jnp.float64(a)),
               dd.from_float(jnp.float64(b * 1e-17)))
    rt = qd.to_dd(qd.from_dd(d))
    assert float(rt.hi) == float(d.hi)
    assert float(rt.lo) == float(d.lo)


@settings(max_examples=50, deadline=None)
@given(finite, finite)
def test_div_beats_binary128(a, b):
    qa = qd.from_float(jnp.float64(a))
    qb = qd.from_float(jnp.float64(b))
    if b == 0:
        return
    got = _qd_frac(qd.div(qa, qb))
    assert _rel(got, Fraction(a) / Fraction(b)) <= QD_TARGET


@settings(max_examples=50, deadline=None)
@given(finite)
def test_sqrt_squares_back(a):
    a = abs(a)
    qa = qd.from_float(jnp.float64(a))
    s = qd.sqrt(qa)
    # sqrt itself is irrational: verify s*s ~ a to the format's precision
    assert _rel(_qd_frac(qd.mul(s, s)), Fraction(a)) <= 2.0 ** -140


def test_where_and_zeros_shapes():
    z = qd.zeros((3, 2))
    assert z.shape == (3, 2) and all(
        float(l.sum()) == 0.0 for l in z.limbs())
    picked = qd.where(jnp.asarray([[True], [False], [True]]),
                      qd.from_float(jnp.ones((3, 2))), z)
    assert np.asarray(qd.to_float(picked)).tolist() == [
        [1.0, 1.0], [0.0, 0.0], [1.0, 1.0]]
