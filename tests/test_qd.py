"""Property tests for quad-word arithmetic: must exceed binary128 (113-bit)."""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dd, qd

# normal-range magnitudes only (XLA CPU flushes subnormals; see efts.py)
finite = st.floats(
    allow_nan=False, allow_infinity=False, min_value=-1e50, max_value=1e50
).filter(lambda x: x == 0.0 or abs(x) > 1e-50)

# binary128 unit roundoff is 2^-113; qd64 must beat it with margin.
QD_TARGET = 2.0**-150


def _qd_frac(x: qd.QD) -> Fraction:
    return sum((Fraction(float(l)) for l in x.limbs()), Fraction(0))


def _rel(got: Fraction, want: Fraction) -> float:
    if want == 0:
        return float(abs(got))
    return abs(float((got - want) / want))


@settings(max_examples=100, deadline=None)
@given(finite, finite)
def test_add_beats_binary128(a, b):
    qa, qb = qd.from_float(jnp.float64(a)), qd.from_float(jnp.float64(b))
    got = _qd_frac(qd.add(qa, qb))
    assert _rel(got, Fraction(a) + Fraction(b)) <= QD_TARGET


@settings(max_examples=100, deadline=None)
@given(finite, finite)
def test_mul_beats_binary128(a, b):
    qa, qb = qd.from_float(jnp.float64(a)), qd.from_float(jnp.float64(b))
    got = _qd_frac(qd.mul(qa, qb))
    want = Fraction(a) * Fraction(b)
    # product of two f64 values fits in 106 bits -> should be (near-)exact
    assert _rel(got, want) <= QD_TARGET


@settings(max_examples=50, deadline=None)
@given(finite, finite, finite, finite)
def test_mul_of_dd_inputs(a, b, c, e):
    qa = qd.from_dd(dd.add(dd.from_float(jnp.float64(a)), dd.from_float(jnp.float64(b * 1e-18))))
    qb = qd.from_dd(dd.add(dd.from_float(jnp.float64(c)), dd.from_float(jnp.float64(e * 1e-18))))
    got = _qd_frac(qd.mul(qa, qb))
    want = _qd_frac(qa) * _qd_frac(qb)
    assert _rel(got, want) <= QD_TARGET


def test_accumulation_chain_precision():
    # Accumulate 512 products; relative error must stay far below 2^-113.
    rng = np.random.default_rng(0)
    a = rng.standard_normal(512)
    b = rng.standard_normal(512)
    acc = qd.from_float(jnp.float64(0.0))
    va = qd.from_float(jnp.asarray(a))
    vb = qd.from_float(jnp.asarray(b))
    prod = qd.mul(va, vb)
    # tree-free sequential fold in one vectorized shot: use renorm over limbs
    # by summing with qd.add pairwise halving
    cur = prod
    m = 512
    while m > 1:
        half = m // 2
        cur = qd.add(qd.QD(*[l[:half] for l in cur.limbs()]), qd.QD(*[l[half : 2 * half] for l in cur.limbs()]))
        m = half
    got = _qd_frac(qd.QD(*[l[0] for l in cur.limbs()]))
    want = sum((Fraction(x) * Fraction(y) for x, y in zip(a, b)), Fraction(0))
    assert _rel(got, want) < 2.0**-140


def test_to_dd_roundtrip():
    q = qd.from_float(jnp.float64(3.5))
    d = qd.to_dd(q)
    assert float(dd.to_float(d)) == 3.5
