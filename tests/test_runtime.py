"""Runtime tests: optimizer variants, data pipeline, checkpoint/failover,
compensated collectives, sharding rules."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.data import DataConfig, TokenStream, make_batch_iterator
from repro.optim import make_optimizer


class TestOptimizer:
    def _quadratic_losses(self, kind, steps=60):
        cfg = RunConfig(optimizer=kind, learning_rate=0.05, warmup_steps=5,
                        total_steps=steps, weight_decay=0.0, grad_clip=10.0)
        init, update = make_optimizer(cfg)
        target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                             jnp.float32)
        params = {"w": jnp.zeros((8, 8), jnp.float32)}
        state = init(params)
        losses = []
        for _ in range(steps):
            grads = {"w": 2 * (params["w"] - target)}
            losses.append(float(jnp.sum((params["w"] - target) ** 2)))
            params, state, _ = update(grads, state, params)
        return losses

    @pytest.mark.parametrize("kind", ["adamw", "adamw_int8", "adamw_dd"])
    def test_convergence(self, kind):
        losses = self._quadratic_losses(kind)
        assert losses[-1] < 0.05 * losses[0], (kind, losses[0], losses[-1])

    def test_dd_master_keeps_small_updates(self):
        # f32 update swallows tiny deltas; df32 master accumulates them
        from repro.core.efts import quick_two_sum, two_sum

        p32 = jnp.float32(1.0)
        hi, lo = jnp.float32(1.0), jnp.float32(0.0)
        delta = jnp.float32(1e-9)  # << ulp(1.0) in f32
        for _ in range(1000):
            p32 = p32 + delta
            s, e = two_sum(hi, delta)
            hi, lo = quick_two_sum(s, e + lo)
        assert float(p32) == 1.0                      # swallowed
        got = float(hi.astype(jnp.float64) + lo.astype(jnp.float64))
        assert abs(got - (1.0 + 1e-6)) < 1e-9         # df32 kept them

    def test_int8_state_roundtrip(self):
        from repro.optim.adamw import _dequantize_int8, _quantize_int8

        x = jnp.asarray(np.random.default_rng(1).standard_normal(1000) * 5,
                        jnp.float32)
        q, s = _quantize_int8(x)
        back = _dequantize_int8(q, s, x.shape)
        assert float(jnp.abs(back - x).max()) < 5 * (2 * 5 / 254)


class TestData:
    def test_deterministic_and_restart_safe(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8)
        s1, s2 = TokenStream(cfg), TokenStream(cfg)
        b1, b2 = s1.batch_at(7), s2.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    def test_sharding_partitions_batch(self):
        full = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
        parts = [
            DataConfig(vocab_size=128, seq_len=16, global_batch=8,
                       shard=i, num_shards=4)
            for i in range(4)
        ]
        assert all(TokenStream(p).local_batch == 2 for p in parts)
        # shards are distinct
        a = TokenStream(parts[0]).batch_at(3)["tokens"]
        b = TokenStream(parts[1]).batch_at(3)["tokens"]
        assert not np.array_equal(a, b)

    def test_prefetch_iterator_resumes(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
        it = make_batch_iterator(cfg, start_step=5)
        b = next(it)
        assert b["step"] == 5
        np.testing.assert_array_equal(
            b["tokens"], TokenStream(cfg).batch_at(5)["tokens"])
        it.close()

    def test_markov_structure_is_learnable(self):
        # successor entropy must be far below uniform
        cfg = DataConfig(vocab_size=256, seq_len=256, global_batch=4)
        toks = TokenStream(cfg).batch_at(0)["tokens"]
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), set()).add(int(b))
        avg_successors = np.mean([len(v) for v in pairs.values()])
        assert avg_successors <= 8.5


class TestCheckpoint:
    def test_atomic_save_restore(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        mgr.save(tree, 10)
        mgr.save(jax.tree.map(lambda x: x * 2, tree), 20)
        restored, meta = mgr.restore(tree)
        assert meta["step"] == 20
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(8.0) * 2)

    def test_keep_k_gc(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        tree = {"x": jnp.zeros(4)}
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(tree, s)
        steps = sorted(os.listdir(tmp_path))
        assert steps == ["step_00000003", "step_00000004"]

    def test_async_save(self, tmp_path):
        from repro.checkpoint import CheckpointManager

        tree = {"x": jnp.arange(1000.0)}
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(tree, 1)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_elastic_reshard_restore(self, tmp_path):
        """Save from one mesh, restore onto a different mesh shape."""
        import subprocess
        import sys

        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_pytree, restore_resharded
from repro.launch.mesh import compat_make_mesh

tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh1 = compat_make_mesh((4, 2), ("data", "model"))
sh1 = NamedSharding(mesh1, P("data", "model"))
tree1 = {{"w": jax.device_put(tree["w"], sh1)}}
save_pytree(tree1, r"{tmp_path}", 1)

mesh2 = compat_make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4])
sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
restored, meta = restore_resharded(tree, r"{tmp_path}", sh2)
assert meta["step"] == 1
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                             capture_output=True, text=True, env=env)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


class TestFailover:
    def test_restart_recovers_and_replays(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        from repro.runtime.failover import SimulatedFailure, run_with_restarts

        mgr = CheckpointManager(str(tmp_path), async_save=False)
        seen = []
        fail_at = {7, 13}

        def make_state(restore_step):
            if restore_step is None:
                return {"acc": jnp.zeros(())}, 0
            state, meta = mgr.restore({"acc": jnp.zeros(())})
            return state, meta["step"]

        def step_fn(state, step):
            if step in fail_at:
                fail_at.discard(step)
                raise SimulatedFailure(f"preempted at {step}")
            seen.append(step)
            return {"acc": state["acc"] + step}

        state, step, failures = run_with_restarts(
            make_state, step_fn, mgr, total_steps=20, checkpoint_every=5,
            max_failures=5)
        assert failures == 2 and step == 20
        # accumulator must equal the deterministic replay value
        assert float(state["acc"]) == sum(range(20))

    def test_failure_budget(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        from repro.runtime.failover import SimulatedFailure, run_with_restarts

        mgr = CheckpointManager(str(tmp_path), async_save=False)

        def make_state(_):
            return {}, 0

        def always_fail(state, step):
            raise SimulatedFailure("dead node")

        with pytest.raises(RuntimeError, match="budget"):
            run_with_restarts(make_state, always_fail, mgr, total_steps=5,
                              max_failures=2)

    def test_restart_backoff_schedule(self):
        from repro.runtime.failover import restart_backoff

        # base=0 (the default) keeps the historical restart-immediately
        # behavior; so does attempt 0
        assert restart_backoff(3) == 0.0
        assert restart_backoff(0, base=0.5) == 0.0
        # exponential under the cap, capped beyond it (jitter disabled)
        waits = [restart_backoff(k, base=0.5, cap=2.0, jitter=0.0)
                 for k in (1, 2, 3, 4)]
        assert waits == [0.5, 1.0, 2.0, 2.0]
        # seeded jitter: deterministic per (seed, attempt), inside
        # [1, 1 + jitter], and distinct across attempts (de-synchronizes a
        # fleet that died at once)
        w1 = restart_backoff(1, base=1.0, jitter=0.25, seed=7)
        assert w1 == restart_backoff(1, base=1.0, jitter=0.25, seed=7)
        assert 1.0 <= w1 <= 1.25
        assert w1 != restart_backoff(2, base=1.0, cap=1.0, jitter=0.25,
                                     seed=7)

    def test_restart_waits_surface_in_on_restart(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        from repro.runtime.failover import (SimulatedFailure,
                                            restart_backoff,
                                            run_with_restarts)

        mgr = CheckpointManager(str(tmp_path / "a"), async_save=False)
        fail_at = {2, 5}
        restarts, legacy, slept = [], [], []

        def make_state_for(mgr):
            def make_state(restore_step):
                if restore_step is None:
                    return {"acc": jnp.zeros(())}, 0
                state, meta = mgr.restore({"acc": jnp.zeros(())})
                return state, meta["step"]
            return make_state

        make_state = make_state_for(mgr)

        def step_fn(state, step):
            if step in fail_at:
                fail_at.discard(step)
                raise SimulatedFailure(f"preempted at {step}")
            return {"acc": state["acc"] + step}

        _, step, failures = run_with_restarts(
            make_state, step_fn, mgr, total_steps=8, checkpoint_every=2,
            max_failures=3, backoff_base=0.001, backoff_max=0.004,
            backoff_jitter=0.5, seed=11,
            on_restart=lambda s, f, w: restarts.append((s, f, w)),
            sleep=slept.append)
        assert failures == 2 and step == 8
        # each restart surfaced the wait it actually slept, and the waits
        # follow the seeded schedule exactly
        want = [restart_backoff(k, base=0.001, cap=0.004, jitter=0.5,
                                seed=11) for k in (1, 2)]
        assert slept == want
        assert [w for (_, _, w) in restarts] == want
        assert [f for (_, f, _) in restarts] == [1, 2]

        # a legacy two-argument callback keeps working
        fail_at.add(2)
        mgr2 = CheckpointManager(str(tmp_path / "b"), async_save=False)
        run_with_restarts(
            make_state_for(mgr2), step_fn, mgr2, total_steps=8,
            checkpoint_every=2, max_failures=3,
            on_restart=lambda s, f: legacy.append((s, f)))
        assert legacy == [(2, 1)]

    def test_watchdog_flags_stragglers(self):
        from repro.runtime.failover import StepWatchdog

        wd = StepWatchdog(threshold=2.0)
        for _ in range(10):
            wd.observe(0, 1.0)
        assert wd.observe(11, 5.0) is True
        assert not wd.observe(12, 1.1)
        assert len(wd.stragglers) == 1


class TestShardingRules:
    def test_rule_resolution_and_elastic_drop(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.sharding import ShardingRules

        mesh = compat_make_mesh((1,), ("data",))
        rules = ShardingRules(mesh=mesh)
        # "model" axis absent from this mesh -> dropped
        assert rules.param_spec("embed", "heads") == P("data", None)
        assert rules.act_spec("batch", "seq", "ffn") == P(("data",), None, None)

    def test_duplicate_axis_suppressed(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import compat_make_mesh
        from repro.runtime.sharding import ShardingRules

        mesh = compat_make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules(mesh=mesh)
        # vocab and heads both map to "model": second use must drop
        spec = rules.param_spec("vocab", "heads")
        assert spec == P("model", None)

    def test_constrain_noop_without_context(self):
        from repro.runtime.sharding import constrain

        x = jnp.ones((4, 4))
        np.testing.assert_array_equal(np.asarray(constrain(x, "batch", None)),
                                      np.ones((4, 4)))
