"""SDP / PDIPM tests — reproduces the paper's Table V claim structure:

double precision stalls near 1e-8..1e-12 relative gap; binary128-class
arithmetic pushes the same algorithm to ~1e-23 gaps with ~1e-32 dual
feasibility (measured on the Lovasz-theta family, the paper's own SDPLIB
problem class).
"""

import numpy as np
import pytest

from repro.core.sdp import random_sdp, solve_sdp, theta_problem

# module-scoped cache: the DD solve is expensive, reuse across assertions
_RESULTS = {}


def _theta_dd():
    if "dd" not in _RESULTS:
        _RESULTS["dd"] = solve_sdp(
            theta_problem(8, 0.4, seed=2), precision="binary128", max_iters=80)
    return _RESULTS["dd"]


def _theta_double():
    if "f64" not in _RESULTS:
        _RESULTS["f64"] = solve_sdp(
            theta_problem(8, 0.4, seed=2), precision="double", max_iters=40)
    return _RESULTS["f64"]


@pytest.mark.slow
def test_binary128_reaches_table_v_band():
    res = _theta_dd()
    # Table V band: relative gaps 1e-22..1e-31, feasibility errors <= 1e-24
    assert res.relative_gap < 1e-20, res.relative_gap
    assert res.p_feas_err < 1e-20
    assert res.d_feas_err < 1e-28


@pytest.mark.slow
def test_double_stalls_binary128_does_not():
    rd = _theta_double()
    rq = _theta_dd()
    # the paper's qualitative claim: >= 10 decades between precisions
    assert rq.relative_gap < 1e-10 * rd.relative_gap


@pytest.mark.slow
def test_objective_agreement():
    # theta number of this graph is integral here (=4): both precisions agree
    rd = _theta_double()
    rq = _theta_dd()
    assert abs(rd.primal_obj - rq.primal_obj) < 1e-6
    assert abs(rq.primal_obj - rq.dual_obj) < 1e-18


def test_double_on_random_sdp_known_optimum():
    prob = random_sdp(8, 5, seed=3)
    res = solve_sdp(prob, precision="double", max_iters=40)
    assert res.relative_gap < 1e-6
    assert abs(res.primal_obj - prob.opt) < 1e-5 * max(1, abs(prob.opt))


@pytest.mark.slow
def test_qd_tier_descends_past_the_dd_floor():
    # ISSUE-2 acceptance: binary128+ reaches <= 1e-20 on random_sdp where
    # the dd tier floors higher.  degeneracy=1e-5 makes two constraints
    # nearly parallel (cond(B) ~ 1e10): the dd Schur-solve noise floors the
    # gap near 1e-24 (observed 1.3e-24, flat over the final iterations);
    # the qd tier's noise sits ~30 decades lower and the SAME algorithm
    # keeps descending and converges (observed 8.9e-28 at 63 iterations,
    # pfeas ~2e-63) — the paper's "binary128 or higher" clause, realized.
    prob = random_sdp(6, 4, seed=3, degeneracy=1e-5)
    rdd = solve_sdp(prob, precision="binary128", max_iters=80)
    rqd = solve_sdp(prob, precision="binary128+", max_iters=90,
                    tol_gap=1e-26)
    assert rqd.relative_gap <= 1e-20, rqd.relative_gap
    assert rqd.converged
    assert rdd.relative_gap > 1e-25, rdd.relative_gap   # dd floors higher
    assert rqd.relative_gap < 1e-2 * rdd.relative_gap
    # ISSUE-4 acceptance: the Schur solves reach the qd accuracy floor via
    # dd-factor + qd-refine (repro.solve rgesv) — measurably cheaper than
    # qd-direct: on this cond(B)~1e10 instance every solve's factorization
    # stays on the dd rung (observed: 118 solves, 0 qd factorizations,
    # gap 6.7e-27 — the qd-direct floor at dd factorization cost)
    st = rqd.schur_stats
    assert st is not None and st["solves"] > 0
    qd_factors = st["factorizations"].get("qd", 0)
    assert qd_factors < st["solves"] // 2, st
    assert st["factorizations"].get("dd", 0) > 0, st


def test_binary192_tier_solves_and_overrides_schur_factor():
    # the td rung of the SDP precision axis: binary192 runs the same PDIPM
    # in 3-limb arithmetic, converging where double stalls, and the Schur
    # path accepts an explicit factor-rung override (its solves then start
    # on that rung of the refinement ladder instead of dd)
    prob = random_sdp(6, 4, seed=3)
    res = solve_sdp(prob, precision="binary192", max_iters=50,
                    tol_gap=1e-18)
    assert res.converged and res.relative_gap <= 1e-18
    assert abs(res.primal_obj - prob.opt) < 1e-8 * max(1, abs(prob.opt))
    res_td = solve_sdp(prob, precision="binary192", max_iters=50,
                       tol_gap=1e-18, schur_factor_tier="td")
    assert res_td.converged
    assert res_td.schur_stats["factorizations"].get("td", 0) > 0
    with pytest.raises(ValueError, match="schur_factor_tier"):
        solve_sdp(prob, precision="double", schur_factor_tier="td")


def test_theta_problem_structure():
    prob = theta_problem(6, 0.5, seed=0)
    assert prob.a[0].shape == (6, 6)
    assert np.allclose(prob.a[0], np.eye(6))
    assert prob.b[0] == 1.0
    # constraint matrices are symmetric
    for a in prob.a:
        assert np.allclose(a, a.T)


def test_random_sdp_certificate():
    # generator must produce a genuinely optimal certificate pair
    prob = random_sdp(8, 4, seed=1)
    # b_i = A_i . X*, and opt = C . X* = b^T y* by construction
    assert prob.opt is not None
    assert np.isfinite(prob.opt)
