"""Unit tests for the tiered iterative-refinement solver (repro.solve).

Covers the refinement contract end-to-end: convergence across
(factor_tier x target_tier) rungs, escalation firing exactly on
stagnation, monotone backward-error histories, NaN-robust escalation when
a cheap rung's factorization breaks down outright, the batched and
sharded multi-RHS paths, factorization reuse, and the compile-once-
per-plan regression (jit-traceable pivots keep the whole refinement step
inside one compiled function).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mp
from repro.core.accuracy import hilbert_f64
from repro.core.linalg import rgetrf, rpotrf
from repro.gemm import matmul
from repro.solve import (
    LADDER_CELLS,
    cholesky_solve_refined,
    lu_solve_refined,
    rgesv,
    rposv,
    tier_eps,
)
from repro.solve import refine as refine_mod

pytestmark = pytest.mark.solver


def _system(n=16, nrhs=2, seed=0, scale=None):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    x = rng.standard_normal((n, nrhs))
    return a, a @ x, x


@pytest.mark.parametrize("factor_tier,target_tier", LADDER_CELLS)
def test_converges_across_ladder(factor_tier, target_tier):
    a, b, x_true = _system()
    x, info = rgesv(a, b, factor_tier=factor_tier, target_tier=target_tier,
                    backend="xla")
    assert info.converged and not info.escalations
    assert info.final_backward_error <= info.tol
    assert mp.precision_of(x) == target_tier
    assert np.abs(np.asarray(mp.to_float(x)) - x_true).max() < 1e-12
    # factored exactly once, at the requested rung
    assert info.factorizations == {factor_tier: 1}


def test_escalation_triggers_exactly_on_stagnation():
    # Hilbert n=14: cond ~ 1e18 crawls at ratio ~0.3 per f64-corrected
    # step — past the stagnation threshold — then one dd correction lands
    # inside tolerance
    n = 14
    h = hilbert_f64(n)
    b = h @ np.ones((n, 1))
    x, info = rgesv(h, b, factor_tier="f64", target_tier="dd",
                    backend="xla", max_iters=25)
    assert info.converged
    assert len(info.escalations) == 1
    assert info.factorizations == {"f64": 1, "dd": 1}
    # the recorded escalations are exactly the iterations whose
    # backward-error ratio crossed the stagnation threshold
    berrs = info.backward_errors
    crossed = set()
    stale = 0.25  # the default stagnation_ratio
    for i in range(2, len(berrs) + 1):
        if berrs[i - 1] > stale * berrs[i - 2] and not crossed:
            crossed.add(i)  # first crossing escalates; ladder then capped
    assert {e["iteration"] for e in info.escalations} == crossed
    for e in info.escalations:
        assert e["ratio"] > stale
        assert (e["from"], e["to"]) == ("f64", "dd")
    # post-escalation iterations run on the escalated rung
    esc_it = info.escalations[0]["iteration"]
    assert all(t == "f64" for t in info.factor_tiers[:esc_it])
    assert all(t == "dd" for t in info.factor_tiers[esc_it:])


def test_backward_error_history_monotone_non_increasing():
    for seed, (ft, tt) in enumerate(LADDER_CELLS):
        a, b, _ = _system(seed=seed)
        _, info = rgesv(a, b, factor_tier=ft, target_tier=tt, backend="xla")
        h = info.backward_errors
        assert all(later <= earlier for earlier, later in zip(h, h[1:])), h


def test_nan_factor_breakdown_escalates_and_recovers():
    # SPD with an eigenvalue (1e-40) far below dd resolution of the large
    # ones: the dd Cholesky goes indefinite under rounding and NaNs; the
    # solver must escalate one rung and still converge.  On the default
    # ladder the next rung is td (~159 bits, resolving cond ~1e40 with
    # room to spare), so the breakdown recovers WITHOUT a qd
    # factorization; the old three-rung ladder must still climb to qd.
    n = 6
    rng = np.random.default_rng(5)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    qq = mp.from_float(jnp.asarray(q), "qd")
    d = mp.from_float(jnp.asarray(np.diag([1.0] * (n - 1) + [1e-40])), "qd")
    b_mat = matmul(matmul(qq, d, backend="xla"),
                   mp.map_limbs(lambda l: l.T, qq), backend="xla")
    rhs = mp.from_float(jnp.asarray(rng.standard_normal((n, 1))), "qd")
    x, info = rposv(b_mat, rhs, factor_tier="dd", target_tier="qd",
                    backend="xla", max_iters=20, tol=1e-30)
    assert info.converged, info.backward_errors
    assert len(info.escalations) == 1
    assert info.factorizations == {"dd": 1, "td": 1}
    assert np.isfinite(np.asarray(mp.to_float(x))).all()
    # the pre-td ladder spelling still climbs straight to qd
    _, info_old = rposv(b_mat, rhs, factor_tier="dd", target_tier="qd",
                        backend="xla", max_iters=20, tol=1e-30,
                        ladder=("f64", "dd", "qd"))
    assert info_old.converged and "qd" in info_old.factorizations


def test_td_rung_spares_the_qd_factorization():
    # The td rung's reason to exist: a system whose conditioning sits
    # between dd's reach (1/u_dd ~ 1e32) and td's (1/u_td ~ 7e47).
    # Hilbert n=26 (cond ~ 1e38) formed IN qd arithmetic — a multi-limb
    # division, so the conditioning is real, not flattened by f64
    # rounding — makes every dd-factored correction stagnate, while a td
    # factorization converges to the qd target.
    #
    # Receipt (the ISSUE acceptance criterion): on the default ladder the
    # solver climbs f64 -> dd -> td and never factors qd; on the old
    # three-rung ladder (f64, dd, qd) the same system must pay for a full
    # qd factorization.
    n = 26
    i = jnp.arange(n, dtype=jnp.float64)
    denom = i[:, None] + i[None, :] + 1.0
    h = mp.div(mp.from_float(jnp.ones((n, n)), "qd"),
               mp.from_float(denom, "qd"))
    b = matmul(h, mp.from_float(jnp.ones((n, 1)), "qd"), backend="xla")

    x_new, info_new = rgesv(h, b, target_tier="qd", backend="xla",
                            max_iters=40)
    assert info_new.converged, info_new.backward_errors
    assert "qd" not in info_new.factorizations, info_new.factorizations
    assert info_new.factorizations.get("td", 0) >= 1
    assert [(e["from"], e["to"]) for e in info_new.escalations] == \
        [("f64", "dd"), ("dd", "td")]
    assert info_new.factor_tiers[-1] == "td"

    x_old, info_old = rgesv(h, b, target_tier="qd", backend="xla",
                            max_iters=40, ladder=("f64", "dd", "qd"))
    assert info_old.converged, info_old.backward_errors
    assert info_old.factorizations.get("qd", 0) >= 1, \
        info_old.factorizations
    # both ladders land the same answer at qd accuracy
    assert np.abs(np.asarray(mp.to_float(mp.sub(x_new, x_old)))).max() \
        < 1e-25


def test_ladder_override_validation():
    a, b, _ = _system()
    # unknown rung
    with pytest.raises(ValueError, match="unknown tier"):
        rgesv(a, b, ladder=("f64", "xx"))
    # not strictly ascending
    with pytest.raises(ValueError, match="ascending"):
        rgesv(a, b, ladder=("dd", "f64"))
    with pytest.raises(ValueError, match="ascending"):
        rgesv(a, b, ladder=("dd", "dd"))
    # factor/target must be rungs of the ladder
    with pytest.raises(ValueError, match="ladder"):
        rgesv(a, b, factor_tier="td", ladder=("f64", "dd", "qd"))
    with pytest.raises(ValueError, match="ladder"):
        rgesv(a, b, target_tier="qd", ladder=("f64", "dd", "td"))
    # a valid custom ladder works and caps the climb at its top rung
    x, info = rgesv(a, b, target_tier="td", ladder=("dd", "td"))
    assert info.converged and mp.precision_of(x) == "td"
    assert info.factorizations == {"dd": 1}


def test_backward_error_is_per_column():
    # LAPACK xGERFS-style metric: a 1e12-scaled RHS column must not mask
    # a small-scale column still above its own backward-error target
    n = 10
    rng = np.random.default_rng(3)
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    b = np.hstack([a @ rng.standard_normal((n, 1)),
                   1e12 * (a @ rng.standard_normal((n, 1)))])
    x, info = rgesv(a, b, factor_tier="f64", target_tier="dd",
                    backend="xla")
    assert info.converged
    from repro.kernels.ref import ddgemm_ref

    a_dd = mp.from_float(jnp.asarray(a), "dd")
    b_dd = mp.from_float(jnp.asarray(b), "dd")
    r = mp.sub(ddgemm_ref(a_dd, x), b_dd)
    rcol = np.max(np.abs(np.asarray(r.hi) + np.asarray(r.lo)), axis=0)
    xcol = np.max(np.abs(np.asarray(mp.to_float(x))), axis=0)
    anorm = np.abs(a).sum(axis=1).max()
    berr_cols = rcol / (anorm * xcol + np.abs(b).max(axis=0))
    assert berr_cols.max() <= info.tol, berr_cols


def test_batched_multi_rhs_matches_looped():
    rng = np.random.default_rng(7)
    n, nrhs, nb = 10, 2, 3
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    b = rng.standard_normal((nb, n, nrhs))
    xb, info = rgesv(a, b, factor_tier="f64", target_tier="dd",
                     backend="xla")
    assert info.converged and xb.shape == (nb, n, nrhs)
    for i in range(nb):
        xi, _ = rgesv(a, b[i], factor_tier="f64", target_tier="dd",
                      backend="xla")
        d = np.abs(np.asarray(mp.to_float(xb[i]))
                   - np.asarray(mp.to_float(xi))).max()
        assert d < 1e-13


def test_sharded_multi_rhs_single_device_mesh():
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("rows",))
    a, b, x_true = _system(n=12, nrhs=3, seed=11)
    x, info = rgesv(a, b, factor_tier="f64", target_tier="dd",
                    backend="xla", mesh=mesh)
    assert info.converged
    assert np.abs(np.asarray(mp.to_float(x)) - x_true).max() < 1e-12


def test_lu_solve_refined_reuses_factorization():
    a, b, _ = _system(n=12, seed=13)
    a_dd = mp.from_float(jnp.asarray(a), "dd")
    lu, piv = rgetrf(a_dd, block=8)
    x, info = lu_solve_refined(a_dd, lu, piv, b, target_tier="qd",
                               backend="xla")
    assert info.converged
    assert info.factorizations == {}  # never re-factored
    assert info.final_backward_error <= info.tol


def test_cholesky_solve_refined_reuses_factorization():
    a, _, _ = _system(n=12, seed=17)
    s = a @ a.T + 12 * np.eye(12)
    rng = np.random.default_rng(17)
    b = s @ rng.standard_normal((12, 2))
    s_dd = mp.from_float(jnp.asarray(s), "dd")
    l = rpotrf(s_dd)
    x, info = cholesky_solve_refined(s_dd, l, b, target_tier="qd",
                                     backend="xla")
    assert info.converged and info.factorizations == {}


def test_target_tier_inferred_from_operand():
    a, b, _ = _system(n=8, seed=19)
    x, info = rgesv(mp.from_float(jnp.asarray(a), "qd"), b,
                    factor_tier="dd", backend="xla")
    assert info.target_tier == "qd" and mp.precision_of(x) == "qd"


def test_rejects_invalid_tiers_and_arg_combos():
    a, b, _ = _system(n=6, seed=23)
    with pytest.raises(ValueError, match="target_tier"):
        rgesv(a, b, factor_tier="f64", target_tier="f64")
    with pytest.raises(ValueError, match="ladder"):
        rgesv(a, b, factor_tier="qd", target_tier="dd")
    with pytest.raises(ValueError, match="assume"):
        rgesv(a, b, assume="sym")
    with pytest.raises(ValueError, match="unknown tier"):
        rgesv(a, b, factor_tier="fp8")
    plan = __import__("repro.gemm", fromlist=["make_plan"]).make_plan(
        6, 6, 2, precision="dd", backend="xla")
    with pytest.raises(ValueError, match="not both"):
        rgesv(a, b, target_tier="dd", plan=plan, backend="xla")


def test_replan_precision_resolves_tier_dependent_params():
    from repro.gemm import make_plan, replan_precision

    p = make_plan(16, 16, 4, precision="dd", backend="ozaki", platform="cpu")
    q = replan_precision(p, 16, 16, 4, "qd")
    assert q.precision == "qd" and q.backend == "xla"  # ozaki has no qd tier
    p2 = make_plan(16, 16, 4, precision="dd", backend="ozaki-pallas",
                   platform="cpu")
    q2 = replan_precision(p2, 16, 16, 4, "qd")
    # the slice fixpoint re-solves for the 212-bit coverage target
    assert q2.backend == "ozaki-pallas" and q2.target_bits == 212
    assert q2.n_slices > p2.n_slices
    assert replan_precision(p2, 16, 16, 4, "dd") is p2  # no-op same tier


def test_rgesv_replans_mismatched_plan_precision():
    from repro.gemm import make_plan

    a, b, _ = _system(n=8, seed=31)
    plan = make_plan(8, 8, 2, precision="dd", backend="xla")
    x, info = rgesv(mp.from_float(jnp.asarray(a), "qd"), b,
                    factor_tier="dd", plan=plan)
    assert info.target_tier == "qd" and info.converged
    assert mp.precision_of(x) == "qd"


def test_rgesv_compiles_once_per_plan():
    # the ISSUE-4 regression: pivots are traced JAX arrays end-to-end, so
    # the whole refinement step jit-compiles once per plan and repeat
    # solves with the same plan re-trace nothing
    n, nrhs = 17, 3  # unique shapes: nothing in this process traced them
    a, b, _ = _system(n=n, nrhs=nrhs, seed=29)
    log = refine_mod._TRACE_EVENTS
    before = len(log)
    rgesv(a, b, factor_tier="dd", target_tier="dd", backend="xla")
    first = log[before:]
    # one residual trace for the plan, one correction trace for the rung
    assert [e[0] for e in first] == ["residual", "correct"]
    mid = len(log)
    rgesv(a, b, factor_tier="dd", target_tier="dd", backend="xla")
    assert len(log) == mid, log[mid:]  # same plan: zero new traces
