"""End-to-end system tests: training converges, failover recovers mid-run,
serving generates, the precision policy engages, HLO cost parsing is sane."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.mark.slow
def test_train_loss_decreases_and_failover_recovers(tmp_path):
    from repro.launch.train import train

    out = train("qwen3-0.6b", steps=90, batch=4, seq=64,
                ckpt_dir=str(tmp_path), inject_failure_at=45,
                verbose=False)
    losses = out["losses"]
    assert out["failures"] == 1  # injected failure was recovered
    # synthetic-markov LM at 90 short steps: modest but monotone progress
    assert np.mean(losses[-10:]) < 0.97 * np.mean(losses[:10]), (
        losses[:10], losses[-10:])


@pytest.mark.slow
def test_train_ssm_family(tmp_path):
    from repro.configs.base import RunConfig
    from repro.launch.train import train

    # 40 short steps: the default warmup (10 steps) burns a quarter of the
    # run at reduced LR and leaves the loss drop marginal — configure the
    # short run explicitly so the test checks learning, not the schedule
    rc = RunConfig(learning_rate=2e-3, warmup_steps=5, total_steps=40,
                   param_dtype="float32", microbatches=1)
    out = train("xlstm-350m", steps=40, batch=4, seq=64, run_cfg=rc,
                verbose=False)
    losses = out["losses"]
    assert np.mean(losses[-5:]) < 0.95 * np.mean(losses[:5])


def test_serve_batched_generates():
    from repro.configs import get_config
    from repro.launch.serve import BatchedServer, Request
    from repro.launch.train import reduce_cfg
    from repro.models import model as M

    cfg = reduce_cfg(get_config("qwen3-0.6b"), d_model=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, batch_slots=2, max_len=64)
    for rid in range(4):
        server.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=5))
    done = server.run()
    assert len(done) == 4
    assert all(len(r.generated) == 5 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)


def test_precision_policy_dd_head():
    from repro.configs import get_config
    from repro.launch.train import reduce_cfg
    from repro.models import model as M

    cfg = reduce_cfg(get_config("qwen3-0.6b"), d_model=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    l_native, _ = M.train_loss(params, cfg, batch, policy={})
    l_dd, _ = M.train_loss(params, cfg, batch, policy={"lm_head": "dd"})
    # dd logits agree with native at f32 level but are not bitwise equal
    assert abs(float(l_native) - float(l_dd)) < 1e-3
    # grads flow through the dd head (straight-through vjp)
    g = jax.grad(lambda p: M.train_loss(p, cfg, batch,
                                        policy={"lm_head": "dd"})[0])(params)
    assert float(jnp.abs(g["embed"]).sum()) > 0


def test_hlo_cost_trip_count_accounting():
    from repro.launch.hlo_cost import analyze_hlo

    n, L, MB = 128, 4, 3

    def f(x, ws):
        def body(c, _):
            y, _ = jax.lax.scan(lambda cc, w: (cc @ w, None), c, ws)
            return y, None
        out, _ = jax.lax.scan(body, x, None, length=MB)
        return out

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L, n, n), jnp.float32)).compile().as_text()
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(2 * n**3 * L * MB, rel=0.01)
    assert sorted(c.while_trip_counts.values()) == [MB, L]


def test_roofline_report_terms():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import model_flops, roofline_report

    cfg = get_config("qwen3-0.6b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    assert 3e15 < mf < 1e16  # ~6*N*D + attention
    rep = roofline_report(cfg, shape, flops_per_dev=mf / 256 * 1.5,
                          bytes_per_dev=1e12,
                          coll={"total": 1e11}, n_devices=256)
    assert rep["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < rep["roofline_fraction"] <= 1.0
    assert 0 < rep["useful_ratio"] <= 1.0


def test_validate_spec():
    from jax.sharding import PartitionSpec as P

    from repro.launch.steps import validate_spec

    class FakeMesh:
        shape = {"model": 16, "data": 4}

    assert validate_spec(FakeMesh, P("model", None), (32, 7)) == P("model", None)
    assert validate_spec(FakeMesh, P("model",), (8,)) == P(None)
    assert validate_spec(FakeMesh, P(("data", "model"),), (64,)) == P(("data", "model"))
    assert validate_spec(FakeMesh, P(("data", "model"),), (32,)) == P(None)
