"""Property + oracle tests for triple-word arithmetic (the td rung).

Mirrors tests/test_qd.py for the 3-limb tier, with one structural change:
the exact-rational (Fraction) oracle tests run unconditionally on seeded
inputs, and only the randomized property sweep is gated on hypothesis
being installed — so the tier keeps real coverage on machines without the
dev extras.

td carries ~159 bits (3 x 53); every gate below beats binary128's 113-bit
significand with margin and sits a few ulp above td's own 2^-159 eps.
"""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dd, mp, qd, td

# binary128 unit roundoff is 2^-113; td must beat it with margin.
TD_TARGET = 2.0**-120

# multi-op chains (dot accumulation, sqrt round-trip) gate a few bits
# above the single-op target but far below dd's 2^-106 capability
TD_CHAIN_TARGET = 2.0**-135


def _td_frac(x) -> Fraction:
    return sum((Fraction(float(l)) for l in x.limbs()), Fraction(0))


def _rel(got: Fraction, want: Fraction) -> float:
    if want == 0:
        return float(abs(got))
    return abs(float((got - want) / want))


def _rand_td(rng, shape=()):
    """A td value with signal in all three limbs (canonical by renorm)."""
    limbs = [jnp.asarray(rng.standard_normal(shape) * s)
             for s in (1.0, 2.0**-53, 2.0**-106)]
    return mp.from_limbs(mp.renorm_list(limbs, k=3))


# --------------------------------------------------------------------------
# deterministic Fraction-oracle tests (always run)
# --------------------------------------------------------------------------


def test_add_mul_fraction_oracle():
    rng = np.random.default_rng(0)
    for _ in range(25):
        a, b = _rand_td(rng), _rand_td(rng)
        fa, fb = _td_frac(a), _td_frac(b)
        assert _rel(_td_frac(td.add(a, b)), fa + fb) <= TD_TARGET
        assert _rel(_td_frac(td.mul(a, b)), fa * fb) <= TD_TARGET
        assert _rel(_td_frac(td.sub(a, b)), fa - fb) <= TD_TARGET


def test_div_fraction_oracle():
    rng = np.random.default_rng(1)
    for _ in range(25):
        a, b = _rand_td(rng), _rand_td(rng)
        fb = _td_frac(b)
        if fb == 0:
            continue
        assert _rel(_td_frac(td.div(a, b)), _td_frac(a) / fb) <= TD_TARGET


def test_fma_fraction_oracle():
    rng = np.random.default_rng(2)
    for _ in range(25):
        acc, a, b = _rand_td(rng), _rand_td(rng), _rand_td(rng)
        got = _td_frac(td.fma(acc, a, b))
        want = _td_frac(acc) + _td_frac(a) * _td_frac(b)
        assert _rel(got, want) <= TD_TARGET


def test_accumulation_chain_precision():
    # Accumulate 512 products; relative error must stay far below 2^-113.
    rng = np.random.default_rng(3)
    a = rng.standard_normal(512)
    b = rng.standard_normal(512)
    va = td.from_float(jnp.asarray(a))
    vb = td.from_float(jnp.asarray(b))
    prod = td.mul(va, vb)
    cur = prod
    m = 512
    while m > 1:
        half = m // 2
        cur = td.add(td.TD(*[l[:half] for l in cur.limbs()]),
                     td.TD(*[l[half:2 * half] for l in cur.limbs()]))
        m = half
    got = _td_frac(td.TD(*[l[0] for l in cur.limbs()]))
    want = sum((Fraction(x) * Fraction(y) for x, y in zip(a, b)),
               Fraction(0))
    assert _rel(got, want) < TD_CHAIN_TARGET


def test_sqrt_squares_back():
    rng = np.random.default_rng(4)
    for _ in range(25):
        a = abs(rng.standard_normal()) * 10.0 ** rng.integers(-20, 20)
        s = td.sqrt(td.from_float(jnp.float64(a)))
        assert _rel(_td_frac(td.mul(s, s)), Fraction(a)) <= TD_CHAIN_TARGET
    # zero guard: sqrt(0) is 0, not NaN from the Heron divide
    z = td.sqrt(td.from_float(jnp.float64(0.0)))
    assert float(td.to_float(z)) == 0.0


def test_renorm_idempotence():
    rng = np.random.default_rng(5)
    for _ in range(25):
        terms = [jnp.float64(rng.standard_normal() * s)
                 for s in (1.0, 1e-16, 1e-32)]
        once = td.renorm_list(terms, k=3, sweeps=3)
        twice = td.renorm_list(once, k=3, sweeps=3)
        for l1, l2 in zip(once, twice):
            assert float(l1) == float(l2)


def test_promotion_roundtrips_exact():
    rng = np.random.default_rng(6)
    d = mp.from_limbs(mp.renorm_list(
        [jnp.asarray(rng.standard_normal(8)),
         jnp.asarray(rng.standard_normal(8) * 2.0**-53)], k=2))
    # climbing pads zero limbs — exact both hops, and descending back
    # re-distills the same value bit for bit
    t = mp.promote(d, "td")
    q = mp.promote(t, "qd")
    back_t = mp.promote(q, "td")
    back_d = mp.promote(back_t, "dd")
    for l1, l2 in zip(mp.limbs(t), mp.limbs(back_t)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for l1, l2 in zip(mp.limbs(d), mp.limbs(back_d)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_to_dd_roundtrip():
    t = td.from_float(jnp.float64(3.5))
    assert float(dd.to_float(td.to_dd(t))) == 3.5
    # from_dd lifts exactly: the third limb is zero
    d = dd.add(dd.from_float(jnp.float64(1.0)),
               dd.from_float(jnp.float64(1e-17)))
    lifted = td.from_dd(d)
    assert float(lifted.x2) == 0.0
    rt = td.to_dd(lifted)
    assert float(rt.hi) == float(d.hi) and float(rt.lo) == float(d.lo)


def test_from_limbs_all_supported_counts():
    # the old mp.from_limbs rejected 3 limbs with "want 2 or 4"; any
    # registered count must construct now, and unknown counts must name
    # the supported set
    one = jnp.float64(1.0)
    assert mp.precision_of(mp.from_limbs([one] * 2)) == "dd"
    assert mp.precision_of(mp.from_limbs([one] * 3)) == "td"
    assert mp.precision_of(mp.from_limbs([one] * 4)) == "qd"
    with pytest.raises(ValueError, match=r"\[2, 3, 4\]"):
        mp.from_limbs([one] * 5)
    with pytest.raises(ValueError, match=r"\[2, 3, 4\]"):
        mp.from_limbs([one])


def test_eps_ordering():
    assert mp.eps("dd") > mp.eps("td") > mp.eps("qd")
    assert mp.eps("td") == 2.0 ** -159


def test_where_and_zeros_shapes():
    z = td.zeros((3, 2))
    assert z.shape == (3, 2) and all(
        float(l.sum()) == 0.0 for l in z.limbs())
    picked = td.where(jnp.asarray([[True], [False], [True]]),
                      td.from_float(jnp.ones((3, 2))), z)
    assert np.asarray(td.to_float(picked)).tolist() == [
        [1.0, 1.0], [0.0, 0.0], [1.0, 1.0]]


def test_mixed_count_add_rejected():
    a = td.from_float(jnp.float64(1.0))
    b = qd.from_float(jnp.float64(1.0))
    with pytest.raises(TypeError):
        mp.add(a, b)


# --------------------------------------------------------------------------
# randomized property sweep (needs hypothesis; mirrors tests/test_qd.py)
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev extras absent
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # normal-range magnitudes only (XLA CPU flushes subnormals)
    finite = st.floats(
        allow_nan=False, allow_infinity=False,
        min_value=-1e50, max_value=1e50,
    ).filter(lambda x: x == 0.0 or abs(x) > 1e-50)

    @settings(max_examples=100, deadline=None)
    @given(finite, finite)
    def test_add_beats_binary128(a, b):
        ta = td.from_float(jnp.float64(a))
        tb = td.from_float(jnp.float64(b))
        got = _td_frac(td.add(ta, tb))
        assert _rel(got, Fraction(a) + Fraction(b)) <= TD_TARGET

    @settings(max_examples=100, deadline=None)
    @given(finite, finite)
    def test_mul_beats_binary128(a, b):
        ta = td.from_float(jnp.float64(a))
        tb = td.from_float(jnp.float64(b))
        # product of two f64 values fits in 106 bits -> exact in td
        assert _rel(_td_frac(td.mul(ta, tb)),
                    Fraction(a) * Fraction(b)) <= TD_TARGET

    @settings(max_examples=50, deadline=None)
    @given(finite, finite, finite, finite)
    def test_mul_of_dd_inputs(a, b, c, e):
        ta = td.from_dd(dd.add(dd.from_float(jnp.float64(a)),
                               dd.from_float(jnp.float64(b * 1e-18))))
        tb = td.from_dd(dd.add(dd.from_float(jnp.float64(c)),
                               dd.from_float(jnp.float64(e * 1e-18))))
        got = _td_frac(td.mul(ta, tb))
        want = _td_frac(ta) * _td_frac(tb)
        assert _rel(got, want) <= TD_TARGET

    @settings(max_examples=50, deadline=None)
    @given(finite, finite, finite)
    def test_add_associativity_error_bound(a, b, c):
        ta, tb, tc = (td.from_float(jnp.float64(v)) for v in (a, b, c))
        want = Fraction(a) + Fraction(b) + Fraction(c)
        left = _td_frac(td.add(td.add(ta, tb), tc))
        right = _td_frac(td.add(ta, td.add(tb, tc)))
        assert _rel(left, want) <= TD_TARGET
        assert _rel(right, want) <= TD_TARGET

    @settings(max_examples=50, deadline=None)
    @given(finite, finite)
    def test_div_beats_binary128(a, b):
        if b == 0:
            return
        got = _td_frac(td.div(td.from_float(jnp.float64(a)),
                              td.from_float(jnp.float64(b))))
        assert _rel(got, Fraction(a) / Fraction(b)) <= TD_TARGET

    @settings(max_examples=100, deadline=None)
    @given(finite, finite)
    def test_from_dd_to_dd_roundtrip_exact(a, b):
        d = dd.add(dd.from_float(jnp.float64(a)),
                   dd.from_float(jnp.float64(b * 1e-17)))
        rt = td.to_dd(td.from_dd(d))
        assert float(rt.hi) == float(d.hi)
        assert float(rt.lo) == float(d.lo)
